//! Bench-trajectory consolidation (ISSUE 8 satellite): collect every
//! per-PR bench report (`BENCH_PR<k>.json`, written at the repo root by
//! the individual benches) into one `BENCH_TRAJECTORY.json` keyed by PR —
//! a single machine-readable artifact tracking how the numbers move as
//! the system grows, instead of N loose files per CI run.
//!
//! Reports merge (never replace): a run that only produced BENCH_PR8.json
//! still keeps earlier PRs' sections that a previous consolidation wrote.
//! Always exits 0 — missing reports are a note, not a failure (a smoke CI
//! pass runs only a subset of benches).
//!
//!   cargo bench --bench bench_trajectory

use std::path::{Path, PathBuf};

use npserve::util::json::{merge_into_file, Value};

/// Repo root (the package root's parent — where benches write reports).
fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("..")
}

/// `BENCH_PR7.json` -> `7`.
fn pr_number(name: &str) -> Option<u32> {
    name.strip_prefix("BENCH_PR")?.strip_suffix(".json")?.parse().ok()
}

fn main() {
    let root = repo_root();
    let out = root.join("BENCH_TRAJECTORY.json");

    let mut reports: Vec<(u32, PathBuf)> = match std::fs::read_dir(&root) {
        Ok(rd) => rd
            .filter_map(|e| e.ok())
            .filter_map(|e| {
                let name = e.file_name().into_string().ok()?;
                Some((pr_number(&name)?, e.path()))
            })
            .collect(),
        Err(e) => {
            eprintln!("could not scan {root:?}: {e}");
            return;
        }
    };
    reports.sort();

    if reports.is_empty() {
        println!("no BENCH_PR*.json reports found under {root:?}; nothing to consolidate");
        return;
    }

    let mut merged = 0usize;
    for (pr, path) in &reports {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("  skipping {path:?}: {e}");
                continue;
            }
        };
        let value = match Value::parse(&text) {
            Ok(v) => v,
            Err(e) => {
                eprintln!("  skipping {path:?}: {e}");
                continue;
            }
        };
        let sections = value.as_obj().map(|m| m.len()).unwrap_or(0);
        match merge_into_file(&out, &format!("PR{pr}"), value) {
            Ok(()) => {
                println!("  PR{pr}: {sections} section(s) from {:?}", path.file_name().unwrap_or_default());
                merged += 1;
            }
            Err(e) => eprintln!("  could not merge {path:?}: {e}"),
        }
    }
    println!("consolidated {merged} report(s) into BENCH_TRAJECTORY.json");
}
