//! Fig 5: accuracy of the quantized (A8-C8-W4) model vs the original
//! bfloat16 model across the 19-benchmark suite.
//!
//! The training/evaluation itself runs in python (`make fig5` →
//! compile/silq.py, the SiLQ reproduction); this bench renders the
//! resulting artifacts/silq/results.json next to the paper's claim and
//! verifies the claim's *shape*: SiLQ ≈ bf16 ≥ PTQ.
//!
//!   cargo bench --bench fig5_accuracy

use npserve::util::json::Value;

fn main() {
    let path = std::path::Path::new("artifacts/silq/results.json");
    let Ok(text) = std::fs::read_to_string(path) else {
        println!("no {path:?} — run `make fig5` first (trains the tiny model + SiLQ QAT)");
        return;
    };
    let v = Value::parse(&text).expect("results.json");
    let b = v.get("benchmarks").unwrap();
    let bf16 = b.get("bf16").unwrap().as_obj().unwrap();
    let ptq = b.get("ptq-w4a8").unwrap().as_obj().unwrap();
    let silq = b.get("silq-w4a8").unwrap().as_obj().unwrap();

    println!("Fig 5 — 19-benchmark accuracy (synthetic suite, DESIGN.md §4 substitution)");
    println!("| benchmark   | bf16  | PTQ-W4A8 | SiLQ-W4A8 |");
    println!("|-------------|-------|----------|-----------|");
    for (name, score) in bf16 {
        println!(
            "| {:11} | {:>5.1} | {:>8.1} | {:>9.1} |",
            name,
            score.as_f64().unwrap(),
            ptq[name].as_f64().unwrap(),
            silq[name].as_f64().unwrap()
        );
    }
    let avg = |m: &std::collections::BTreeMap<String, Value>| {
        m.values().map(|v| v.as_f64().unwrap()).sum::<f64>() / m.len() as f64
    };
    let (a_bf, a_ptq, a_silq) = (avg(bf16), avg(ptq), avg(silq));
    println!("| **average** | {a_bf:>5.1} | {a_ptq:>8.1} | {a_silq:>9.1} |");
    println!(
        "\npaper (Granite-3.3-8b, real benchmarks): quantized 56.8 vs bf16 56.4 — \
         QAT matches bf16."
    );
    println!(
        "shape check: SiLQ within 1 pt of bf16: {} | PTQ below SiLQ: {}",
        if (a_silq - a_bf).abs() <= 1.0 { "PASS" } else { "FAIL" },
        if a_ptq < a_silq { "PASS" } else { "FAIL" },
    );
}
