//! Hot-path microbenchmarks: PJRT stage dispatch, card-chain round-trip,
//! broker ops, tokenizer, tensor codec. Used by the §Perf pass
//! (EXPERIMENTS.md) — the L3 coordinator must not be the bottleneck.
//! Results are appended to BENCH_PR1.json (§hotpath) for CI trending.
//!
//!   cargo bench --bench runtime_hotpath

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::sync::Mutex;
use std::time::Instant;

use npserve::broker::{Broker, Task};
use npserve::runtime::{Engine, Tensor, TensorView};
use npserve::service::{GenRequest, LlmInstance, SharedEngine};
use npserve::tokenizer::ByteTokenizer;
use npserve::util::json::{merge_into_file, Value};
use npserve::util::stats::fmt_time;

/// (name, seconds/iter) rows accumulated for BENCH_PR1.json.
static RESULTS: Mutex<Vec<(String, f64)>> = Mutex::new(Vec::new());

fn bench<F: FnMut()>(name: &str, iters: u32, mut f: F) -> f64 {
    // warmup
    for _ in 0..iters.div_ceil(10) {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("  {name:<44} {:>12}/iter", fmt_time(per));
    RESULTS.lock().unwrap().push((name.to_string(), per));
    per
}

fn write_report() {
    let rows = RESULTS.lock().unwrap();
    let section = Value::obj(
        rows.iter()
            .map(|(name, per)| (name.as_str(), Value::num(*per)))
            .collect(),
    );
    // cargo runs bench binaries with cwd = the package root (rust/); the
    // report lives one level up, at the repo root (EXPERIMENTS.md)
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_PR1.json");
    match merge_into_file(&path, "hotpath", section) {
        Ok(()) => println!("\nwrote BENCH_PR1.json §hotpath ({} rows)", rows.len()),
        Err(e) => eprintln!("\ncould not write BENCH_PR1.json: {e}"),
    }
}

fn main() {
    println!("== L3 coordinator micro-benches ==");
    let broker = Broker::new();
    let mut id = 0u64;
    bench("broker post+consume (priority queue)", 10_000, || {
        id += 1;
        broker.post("q", Task { id, priority: (id % 3) as u8, body: "x".into(), reply_to: id, retries: 0, resume_from: 0, prefix_hash: 0, max_tokens: 0 });
        broker.try_consume("q", &[0, 1, 2]).unwrap();
        broker.remove_response(id);
    });

    let tok = ByteTokenizer;
    let text = "The quick brown fox jumps over the lazy dog. 12+34=46;";
    bench("tokenize+detokenize 55-byte prompt", 100_000, || {
        let t = tok.encode(text);
        std::hint::black_box(tok.decode(&t));
    });

    let tensor = Tensor::f32(vec![8, 128], vec![0.5; 1024]);
    bench("tensor wire encode+decode [8,128] f32", 100_000, || {
        let w = tensor.to_wire();
        std::hint::black_box(Tensor::from_wire(&w).unwrap());
    });

    let wire = tensor.to_wire();
    let mut frame = Vec::with_capacity(wire.len());
    bench("tensor wire view decode + pooled encode", 100_000, || {
        let (v, _) = TensorView::parse(&wire).unwrap();
        frame.clear();
        npserve::runtime::WireEncode::encode_wire_into(&v, &mut frame);
        std::hint::black_box(&frame);
    });

    // PJRT paths need artifacts
    let dir = PathBuf::from("artifacts/granite-test");
    if !dir.join("manifest.json").exists() {
        println!("(skipping PJRT benches: run `make artifacts`)");
        write_report();
        return;
    }
    println!("\n== PJRT stage dispatch (granite-test artifacts) ==");
    let engine = SharedEngine(Arc::new(Engine::load(&dir).unwrap()));
    let m = engine.manifest.clone();
    let b = m.batch_slots;

    let toks = Tensor::i32(vec![b], vec![1; b]);
    bench("embed_decode stage (host->device->host)", 2_000, || {
        std::hint::black_box(engine.run("embed_decode", &[toks.clone()]).unwrap());
    });

    let h = Tensor::f32(vec![b, m.d_model], vec![0.1; b * m.d_model]);
    bench(&format!("lmhead shard [{b},{}]", m.d_model), 2_000, || {
        std::hint::black_box(engine.run("lmhead_0", &[h.clone()]).unwrap());
    });

    println!("\n== full service round-trips ==");
    let inst = LlmInstance::start(engine);
    let mut rid = 0;
    let per = bench("decode round via card chain (B slots)", 50, || {
        rid += 1;
        inst.submit(GenRequest {
            id: rid, prompt: "ab".into(), max_tokens: 2,
            temperature: 0.0, top_k: 0, stop_byte: None,
            retries: 0,
            resume_from: 0,
            prefix_hash: 0,
            affinity: false,
            cancel: None,
        });
        inst.serve_until_drained();
    });
    println!(
        "  -> effective decode ITL on CPU PJRT ≈ {} for {} layers",
        fmt_time(per / 2.0),
        m.n_layers
    );
    write_report();
}
