//! [6] / §II-C: a standalone 16-card NorthPole LLM server node running the
//! 3B model delivers ~28,356 tok/s at sub-1 ms/token per-user latency and
//! 672 W aggregate card power; a rack runs 18 such instances (intro).
//!
//!   cargo bench --bench node3b_throughput

use npserve::config::hw::RackSpec;
use npserve::config::models::find_model;
use npserve::mapper::map_model;
use npserve::metrics::BatchMetrics;
use npserve::pipeline::sim::{simulate, SimConfig};
use npserve::power::card_power_w;

fn main() {
    let rack = RackSpec::northpole_42u();
    let m = find_model("granite-3.1-3b").unwrap();
    let mapping = map_model(&m, 28, 2048, &rack).unwrap();
    println!(
        "granite-3.1-3b ({}): {} cards / {} node(s) / {} stages / micro-batch {}",
        m.precision,
        mapping.n_cards(),
        mapping.n_nodes(&rack),
        mapping.stages.len(),
        mapping.micro_batch
    );

    let rep = simulate(&mapping, &rack, SimConfig {
        users: 28, prompt_len: 512, gen_len: 512, requests: 56, chunk: 512,
    });
    let met = BatchMetrics::from_records(&rep.seqs);
    println!("\n| metric            | measured | paper [6] |");
    println!("|-------------------|----------|-----------|");
    println!("| ITL per user      | {:>6.2}ms | <1 ms     |", met.itl.mean() * 1e3);
    println!("| node throughput   | {:>7.0}  | 28,356    |", met.otps);
    let per_card = card_power_w(&rack.node, rep.mean_card_busy().min(0.25));
    println!("| card power x16    | {:>6.0} W | 672 W     |", per_card * 16.0);
    println!(
        "| rack instances    | {:>8} | 18        |",
        mapping.instances_per_rack(&rack)
    );
    let rack_tps = met.otps * mapping.instances_per_rack(&rack) as f64;
    println!("| rack throughput   | {:>7.0}  | ~510k     |", rack_tps);
    println!(
        "\nshape: ITL sub-1ms {}, node ~28k tok/s {}",
        if met.itl.mean() < 1.2e-3 { "PASS" } else { "FAIL" },
        if (20_000.0..40_000.0).contains(&met.otps) { "PASS" } else { "FAIL" },
    );
}
