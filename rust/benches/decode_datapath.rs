//! Decode-datapath benchmark (EXPERIMENTS.md §Decode-datapath): bytes
//! copied and buffers allocated per generated token, copy-path vs
//! zero-copy, over the full broker-to-head serving stack on the
//! stub-backend toy model (`runtime::testmodel` — no PJRT artifacts
//! needed, so this runs in every CI pass).
//!
//! * **copy path** (`ServeOptions { resident_kv: false }`): each layer's
//!   KV cache round-trips through host literals on every decode step of
//!   every layer — the PR-1 discipline (PR-1 additionally paid owned
//!   packet decodes and fresh per-hop frames, so this baseline is
//!   conservative);
//! * **zero-copy** (default): resident device KV donated per step and
//!   aliased in place, borrowed wire views, pooled packet frames.
//!
//! Acceptance bars (ISSUE 2):
//! * ≥ 2x reduction in bytes copied per decode round,
//! * resident per-token traffic must NOT scale with the KV-cache size
//!   (measured by re-running with 8x the context window).
//!
//! Byte counts come from `util::traffic` (relaxed global counters at the
//! wire/device boundaries); the bench runs one workload at a time and
//! diffs snapshots around it. Results land in BENCH_PR2.json
//! §decode_datapath.
//!
//!   cargo bench --bench decode_datapath

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use npserve::runtime::testmodel::ToyConfig;
use npserve::service::{GenRequest, LlmInstance, ServeOptions, SharedEngine};
use npserve::util::json::{merge_into_file, Value};
use npserve::util::traffic;

/// Cargo runs bench binaries with cwd = the package root (rust/); the
/// report lives one level up, at the repo root (EXPERIMENTS.md).
fn report_path() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_PR2.json")
}

struct Measured {
    bytes_per_tok: f64,
    allocs_per_tok: f64,
    tokens: usize,
    wall_s: f64,
}

/// Serve one prompt to completion and meter the datapath. A single
/// sequence keeps the decode-packet count exact (one packet per token
/// after the prefill chunk — a per-sequence [1,D] packet since ISSUE 4),
/// so byte counts are deterministic and the scaling assertion cannot
/// flake on scheduler timing.
fn run(cfg: &ToyConfig, resident: bool, max_tokens: usize) -> Measured {
    let engine = SharedEngine(Arc::new(cfg.engine()));
    let inst = LlmInstance::start_with(
        engine,
        ServeOptions { resident_kv: resident, ..Default::default() },
    );
    let req = |id: u64, max_tokens: usize| GenRequest {
        id,
        prompt: "ab".into(),
        max_tokens,
        temperature: 0.0,
        top_k: 0,
        stop_byte: None,
        retries: 0,
        resume_from: 0,
        prefix_hash: 0,
        affinity: false,
        cancel: None,
    };
    // warmup: primes the frame pool and the serving loop's row buffers
    inst.submit(req(1000, 2));
    inst.serve_until_drained();

    let before = traffic::snapshot();
    let t0 = Instant::now();
    inst.submit(req(0, max_tokens));
    let recs = inst.serve_until_drained();
    let wall_s = t0.elapsed().as_secs_f64();
    let d = traffic::snapshot().since(&before);
    inst.shutdown();

    let tokens: usize = recs
        .iter()
        .filter(|r| r.id == 0)
        .map(|r| r.n_out as usize)
        .sum();
    assert_eq!(tokens, max_tokens, "the request must complete fully");
    Measured {
        bytes_per_tok: d.bytes_copied as f64 / tokens as f64,
        allocs_per_tok: d.allocations as f64 / tokens as f64,
        tokens,
        wall_s,
    }
}

fn fmt_kib(b: f64) -> String {
    format!("{:.1} KiB", b / 1024.0)
}

fn main() {
    let cfg = ToyConfig::small();
    let mut big = cfg;
    big.max_context = cfg.max_context * 8; // 8x KV cache, same workload
    // fits the small config's max_context=32 (2 prompt + 25 generated + 1)
    let max_tokens = 25; // 1-chunk prefill + exactly 24 decode rounds
    let b = cfg.batch_slots;

    println!(
        "== decode datapath: toy model, {} layers, B={b}, D={}, KV {}B/layer ==",
        cfg.n_layers,
        cfg.d_model,
        cfg.kv_bytes_per_layer()
    );
    let copy = run(&cfg, false, max_tokens);
    println!(
        "  copy path (host KV round-trip)   {:>12}/tok  {:>7.1} allocs/tok  ({} toks in {:.2}s)",
        fmt_kib(copy.bytes_per_tok), copy.allocs_per_tok, copy.tokens, copy.wall_s
    );
    let zero = run(&cfg, true, max_tokens);
    println!(
        "  zero-copy (resident KV donated)  {:>12}/tok  {:>7.1} allocs/tok  ({} toks in {:.2}s)",
        fmt_kib(zero.bytes_per_tok), zero.allocs_per_tok, zero.tokens, zero.wall_s
    );
    let reduction = copy.bytes_per_tok / zero.bytes_per_tok;
    let alloc_reduction = copy.allocs_per_tok / zero.allocs_per_tok.max(1e-9);
    println!("  -> bytes-copied reduction {reduction:.2}x (bar: ≥ 2x), allocs {alloc_reduction:.2}x");

    // Residency: per-token traffic must be independent of KV-cache size.
    println!("\n== KV-size scaling (max_context {} -> {}) ==", cfg.max_context, big.max_context);
    let copy_big = run(&big, false, max_tokens);
    let zero_big = run(&big, true, max_tokens);
    let copy_scale = copy_big.bytes_per_tok / copy.bytes_per_tok;
    let zero_scale = zero_big.bytes_per_tok / zero.bytes_per_tok;
    println!("  copy path scales      {copy_scale:.2}x (KV round-trip grows with context)");
    println!("  zero-copy scales      {zero_scale:.2}x (bar: ≤ 1.1x — resident KV never moves)");

    let section = Value::obj(vec![
        ("layers", Value::num(cfg.n_layers as f64)),
        ("batch_slots", Value::num(b as f64)),
        ("kv_bytes_per_layer", Value::num(cfg.kv_bytes_per_layer() as f64)),
        ("tokens", Value::num(zero.tokens as f64)),
        ("copy_bytes_per_tok", Value::num(copy.bytes_per_tok)),
        ("zerocopy_bytes_per_tok", Value::num(zero.bytes_per_tok)),
        ("bytes_reduction", Value::num(reduction)),
        ("copy_allocs_per_tok", Value::num(copy.allocs_per_tok)),
        ("zerocopy_allocs_per_tok", Value::num(zero.allocs_per_tok)),
        ("allocs_reduction", Value::num(alloc_reduction)),
        ("kv_scale_factor", Value::num((big.max_context / cfg.max_context) as f64)),
        ("copy_bytes_scaling", Value::num(copy_scale)),
        ("zerocopy_bytes_scaling", Value::num(zero_scale)),
    ]);
    match merge_into_file(&report_path(), "decode_datapath", section) {
        Ok(()) => println!("\nwrote BENCH_PR2.json §decode_datapath"),
        Err(e) => eprintln!("\ncould not write BENCH_PR2.json: {e}"),
    }

    let mut failed = false;
    if reduction < 2.0 {
        eprintln!("FAIL: bytes-copied reduction {reduction:.2}x below the 2x acceptance bar");
        failed = true;
    }
    if zero_scale > 1.1 {
        eprintln!(
            "FAIL: resident per-token traffic scaled {zero_scale:.2}x with an 8x KV cache \
             (must stay flat)"
        );
        failed = true;
    }
    if failed {
        std::process::exit(1);
    }
    println!("decode_datapath OK");
}
