//! ISSUE 4 integration: per-sequence decode packets (the paper's §V-C
//! micro-batch-1 regime) on the stub-backend toy model — runs in every CI
//! pass, no PJRT artifacts needed.
//!
//! The contract under test: per-sequence decode is the batched round
//! *restricted to one slot*. Greedy outputs must be byte-identical between
//! the two regimes (at 1 and at `batch_slots` concurrent sequences), a
//! slot decoding into the last cache line must not collide with the
//! batched baseline's masked-row convention, the per-sequence serving
//! loop must actually pipeline (≥ 2 decode packets concurrently in
//! flight), and broker clients must see their first token while the batch
//! is still generating.

use std::sync::Arc;

use npserve::broker::{Broker, Task};
use npserve::npruntime::StageExecutor;
use npserve::runtime::testmodel::ToyConfig;
use npserve::runtime::Tensor;
use npserve::service::{
    GenRequest, GenUpdate, LayerExecutor, LlmInstance, PacketHeader, ServeOptions,
    SharedEngine,
};

fn stub_engine(cfg: &ToyConfig) -> SharedEngine {
    SharedEngine(Arc::new(cfg.engine()))
}

fn opts(per_seq: bool) -> ServeOptions {
    ServeOptions { per_seq_decode: per_seq, ..Default::default() }
}

fn req(id: u64, prompt: &str, max_tokens: usize) -> GenRequest {
    GenRequest {
        id,
        prompt: prompt.into(),
        max_tokens,
        temperature: 0.0,
        top_k: 0,
        stop_byte: None,
        retries: 0,
        resume_from: 0,
        prefix_hash: 0,
        affinity: false,
        cancel: None,
    }
}

/// Serve `reqs` on a fresh instance and return each request's token
/// stream, keyed by position in `reqs`.
fn serve(cfg: &ToyConfig, per_seq: bool, reqs: &[GenRequest]) -> Vec<Vec<u32>> {
    let inst = LlmInstance::start_with(stub_engine(cfg), opts(per_seq));
    for r in reqs {
        inst.submit(r.clone());
    }
    inst.serve_until_drained();
    let updates = inst.updates.lock().unwrap();
    let mut out = vec![Vec::new(); reqs.len()];
    while let Ok(u) = updates.try_recv() {
        if let GenUpdate::Token { id, token, .. } = u {
            let i = reqs.iter().position(|r| r.id == id).expect("unknown id");
            out[i].push(token);
        }
    }
    out
}

/// The tentpole acceptance: greedy outputs byte-identical per-seq vs
/// batched, at 1 and at `batch_slots` concurrent sequences with mixed
/// prompt lengths and generation lengths.
#[test]
fn greedy_per_seq_matches_batched_byte_identical() {
    let cfg = ToyConfig::small();
    // one sequence
    let solo = [req(7, "hello", 8)];
    let batched = serve(&cfg, false, &solo);
    let per_seq = serve(&cfg, true, &solo);
    assert_eq!(batched[0].len(), 8);
    assert_eq!(batched, per_seq, "solo sequence diverged");

    // a full batch of mixed lengths (multi-chunk prefill + staggered
    // retirement: slots finish at different rounds)
    let reqs = [
        req(1, "a", 8),
        req(2, "a longer prompt spanning chunks", 5),
        req(3, "mid", 3),
        req(4, "another one", 7),
    ];
    assert_eq!(reqs.len(), cfg.batch_slots);
    let batched = serve(&cfg, false, &reqs);
    let per_seq = serve(&cfg, true, &reqs);
    for (i, r) in reqs.iter().enumerate() {
        assert_eq!(batched[i].len(), r.max_tokens, "req {} truncated", r.id);
        assert_eq!(batched[i], per_seq[i], "req {} diverged", r.id);
    }
}

/// Slot isolation: a prompt generates the same tokens whether it runs
/// alone (slot 0) or alongside a full batch (any slot), in both decode
/// regimes. (Pinned here because the toy MLP once leaked the slot index
/// into the row transform, which made this untestable on the stub
/// backend.)
#[test]
fn batch_company_does_not_change_a_sequence() {
    let cfg = ToyConfig::small();
    let lone = serve(&cfg, true, &[req(9, "isolated", 6)]);
    for per_seq in [false, true] {
        let reqs = [
            req(1, "noise one", 6),
            req(9, "isolated", 6),
            req(3, "noise two", 4),
            req(4, "noise three", 5),
        ];
        let out = serve(&cfg, per_seq, &reqs);
        assert_eq!(out[1], lone[0], "per_seq={per_seq}: batch company changed output");
    }
}

/// Context-boundary decode (ISSUE 4 satellite): a sequence that runs into
/// `max_context` must retire cleanly — exactly `max_context - n_in`
/// tokens, no panic, identical across regimes — while other slots are
/// mid-flight, i.e. while the batched baseline is writing masked dummy
/// rows at the last cache line (`positions.fill(max_ctx - 1)`).
#[test]
fn context_boundary_retires_cleanly_in_both_regimes() {
    let cfg = ToyConfig::small();
    let max_ctx = cfg.max_context;
    // max_tokens ≫ context: admission clamps the prompt to one token and
    // generation must stop at the context edge (position max_ctx - 1)
    let boundary = req(1, "xy", max_ctx * 2);
    let company = [
        boundary.clone(),
        req(2, "co one", 4),
        req(3, "co two", 6),
        req(4, "co three", 3),
    ];
    let batched = serve(&cfg, false, &company);
    let per_seq = serve(&cfg, true, &company);
    // n_in clamps to 1, so the boundary slot generates max_ctx - 1 tokens
    assert_eq!(batched[0].len(), max_ctx - 1, "batched did not fill the context");
    assert_eq!(per_seq[0].len(), max_ctx - 1, "per-seq did not fill the context");
    for i in 0..company.len() {
        assert_eq!(batched[i], per_seq[i], "req {} diverged at the boundary", i + 1);
    }
}

/// The masked-row collision itself, pinned at the packet level: in the
/// batched baseline, idle slots write (masked, never-attended) KV at cache
/// line `max_ctx - 1`. A later *real* decode of that slot at position
/// `max_ctx - 1` must overwrite the garbage before attending — its output
/// must match an executor whose cache never saw a masked write at all.
#[test]
fn masked_row_cache_line_is_overwritten_by_real_boundary_decode() {
    let cfg = ToyConfig::small();
    let e = stub_engine(&cfg);
    let b = cfg.batch_slots;
    let last = cfg.max_context as i32 - 1;
    let dirty = LayerExecutor::new(e.clone(), 0);
    let clean = LayerExecutor::new(e.clone(), 0);
    let step = |ex: &dyn StageExecutor, packet: &[u8]| {
        let mut out = Vec::new();
        ex.execute(0, 0, packet, &mut out);
        out
    };
    // batched round with slot 0 masked (the serving loop's convention for
    // idle/filling slots: token 0 at the last cache line) while slot 1
    // decodes for real — pollutes slot 0's line max_ctx-1 on `dirty`
    let mut toks = vec![0i32; b];
    let mut pos = vec![last; b];
    toks[1] = 5;
    pos[1] = 0;
    let h = e
        .run("embed_decode", &[Tensor::i32(vec![b], toks)])
        .unwrap()
        .remove(0);
    let pos_t = Tensor::i32(vec![b], pos);
    step(dirty.as_ref(), &PacketHeader::decode_step().encode(&[&h, &pos_t]));
    // now slot 0 decodes for real at the last line, on both executors
    let h1 = e
        .run("embed_decode_seq", &[Tensor::i32(vec![1], vec![9])])
        .unwrap()
        .remove(0);
    let hdr = PacketHeader::decode_seq(0, last);
    let packet = hdr.encode(&[&h1]);
    let out_dirty = step(dirty.as_ref(), &packet);
    let out_clean = step(clean.as_ref(), &packet);
    assert_eq!(
        out_dirty, out_clean,
        "masked dummy row leaked into a real boundary decode"
    );
}

/// The per-sequence loop must actually pipeline: with a full batch
/// decoding, at least two decode packets are concurrently in flight
/// (deterministic: a slot's flag clears only when its completion is
/// routed, and the injection pass submits every ready slot first). The
/// batched baseline never exceeds one.
#[test]
fn per_seq_keeps_multiple_decode_packets_in_flight() {
    let cfg = ToyConfig::small();
    let reqs: Vec<GenRequest> =
        (0..cfg.batch_slots as u64).map(|i| req(i, "prompt", 6)).collect();

    let inst = LlmInstance::start_with(stub_engine(&cfg), opts(true));
    for r in &reqs {
        inst.submit(r.clone());
    }
    inst.serve_until_drained();
    assert!(
        inst.decode_packets_hwm() >= 2,
        "per-seq decode never pipelined: hwm {}",
        inst.decode_packets_hwm()
    );

    let inst = LlmInstance::start_with(stub_engine(&cfg), opts(false));
    for r in &reqs {
        inst.submit(r.clone());
    }
    inst.serve_until_drained();
    assert_eq!(
        inst.decode_packets_hwm(),
        1,
        "batched baseline must keep exactly one decode round in flight"
    );
}

/// Single-token completions carry no inter-token latency: `Done.itl_s`
/// must be `None` (ISSUE 4 satellite — a fake 0.0 deflated fleet ITL
/// averages downstream).
#[test]
fn single_token_done_reports_no_itl() {
    let cfg = ToyConfig::small();
    let inst = LlmInstance::start_with(stub_engine(&cfg), opts(true));
    inst.submit(req(1, "one token only", 1));
    inst.serve_until_drained();
    let updates = inst.updates.lock().unwrap();
    let mut saw_done = false;
    while let Ok(u) = updates.try_recv() {
        if let GenUpdate::Done { n_out, itl_s, .. } = u {
            assert_eq!(n_out, 1);
            assert_eq!(itl_s, None, "single-token completion fabricated an ITL");
            saw_done = true;
        }
    }
    assert!(saw_done);
}

/// Broker streaming is live (ISSUE 4 satellite): the first `Token` must
/// reach the client's response channel while the batch is still
/// generating — not buffered until `serve_until_drained` returns. With
/// per-row model work dialed up, the first of 24 tokens arrives with
/// ~200 ms of generation still to go, so the instance cannot have
/// recorded the sequence as finished yet.
#[test]
fn broker_client_sees_first_token_before_batch_done() {
    // ~9 ms of model work per generated token: after the first token
    // arrives, ≥ 200 ms of generation remain — a comfortable window to
    // observe "still generating"
    let cfg = ToyConfig { row_work_ns: 3_000_000, ..ToyConfig::small() };
    let inst = LlmInstance::start_with(stub_engine(&cfg), opts(true));
    let broker = Broker::new();
    let ch = broker.post(
        "toy",
        Task { id: 1, priority: 1, body: "stream me".into(), reply_to: 42, retries: 0, resume_from: 0, prefix_hash: 0, max_tokens: 0 },
    );
    let max_tokens = (cfg.max_context - cfg.prefill_chunk).min(24);
    let handle = inst.serve_broker(broker.clone(), "toy", vec![0, 1, 2], max_tokens);
    let _first = ch.recv().expect("stream closed without a single token");
    // the moment the first token reaches the client, generation of the
    // remaining tokens is still in flight: no record exists yet
    let finished = inst
        .records
        .lock()
        .unwrap()
        .iter()
        .any(|r| r.id == 42);
    assert!(
        !finished,
        "first token only arrived after the batch drained (buffered streaming)"
    );
    // drain the rest; the stream must still complete and close
    let mut n = 1;
    while ch.recv().is_some() {
        n += 1;
    }
    assert_eq!(n, max_tokens, "stream delivered {n} of {max_tokens} tokens");
    broker.close("toy");
    assert_eq!(handle.join().unwrap(), 1);
    inst.shutdown();
}
