//! ISSUE 5 acceptance: the queue-driven rack autoscaler, proven by a
//! deterministic harness. Every test drives the control loop through the
//! injected tick interface (`Autoscaler::tick`) — zero sleeps and zero
//! wall-clock reads in the assertions; where a test must wait for a
//! worker thread to observe a flag it spins on the drain-completion
//! signal with `yield_now`. Covered:
//!
//! * depth-triggered scale-up (sustained window, not one spike)
//! * typed overcommit backoff (doubling, no deploy retry storm)
//! * hysteresis: an oscillating load trace crossing the threshold faster
//!   than `up_after` never flaps the fleet
//! * drain-before-teardown: scale-down marks `ScalingDown`, waits for the
//!   drain-completion signal, and never kills in-flight sequences
//! * the release-gated soak: a fixed-seed traffic wave against a
//!   2-instance-max policy completes byte-identically to a statically
//!   provisioned 2-instance fleet, with the event log pinned to a golden
//!   sequence (dumped to AUTOSCALE_LOG.json for the CI failure artifact).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use npserve::broker::{ResponseChannel, Task};
use npserve::config::hw::RackSpec;
use npserve::metrics::{AutoscaleLog, ScaleAction, ScaleOutcome, ScaleTrigger};
use npserve::rack::{Autoscaler, InstanceSpec, InstanceState, ModelScaler, RackService, ScalePolicy};
use npserve::runtime::testmodel::ToyConfig;
use npserve::service::SharedEngine;
use npserve::util::prng::Rng;

const MODEL: &str = "toy-testmodel";
const CARDS: usize = 4;

/// Toy geometry for the soak: slow enough (busy-work per attended row)
/// that a 40-request wave is still queued when the first control ticks
/// sample it, fast enough that the whole story runs in milliseconds.
fn soak_config() -> ToyConfig {
    let mut cfg = ToyConfig::small();
    cfg.row_work_ns = 20_000;
    cfg
}

/// A live instance serving the broker's full priority range.
fn live_spec() -> InstanceSpec {
    let mut s = InstanceSpec::live(MODEL, CARDS, SharedEngine(Arc::new(soak_config().engine())));
    s.max_tokens = 8;
    s
}

/// A live instance subscribed to priority 2 only: priority-0 tasks posted
/// by a test are never consumed, so queue depth is under exact test
/// control — the deterministic load source for the control-loop tests.
fn premium_only_spec() -> InstanceSpec {
    let mut s = premium_base();
    s.priorities = vec![2];
    s
}

fn premium_base() -> InstanceSpec {
    let mut s =
        InstanceSpec::live(MODEL, CARDS, SharedEngine(Arc::new(ToyConfig::small().engine())));
    s.max_tokens = 8;
    s
}

fn post_synthetic(svc: &RackService, n: usize, base: u64) {
    for i in 0..n {
        svc.broker().post(
            MODEL,
            Task {
                id: base + i as u64,
                priority: 0,
                body: format!("synthetic-{}", base + i as u64),
                reply_to: base + i as u64,
                retries: 0,
                resume_from: 0,
                prefix_hash: 0,
                max_tokens: 0,
            },
        );
    }
}

fn drain_synthetic(svc: &RackService) {
    while svc.broker().try_consume(MODEL, &[0]).is_some() {}
}

fn post_wave(svc: &RackService, prompts: &[String]) -> Vec<(u64, Arc<ResponseChannel>)> {
    prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let id = 100 + i as u64;
            (
                id,
                svc.broker().post(
                    MODEL,
                    Task { id: i as u64, priority: (i % 3) as u8, body: p.clone(), reply_to: id, retries: 0, resume_from: 0, prefix_hash: 0, max_tokens: 0 },
                ),
            )
        })
        .collect()
}

fn collect(chans: Vec<(u64, Arc<ResponseChannel>)>) -> BTreeMap<u64, String> {
    let mut out = BTreeMap::new();
    for (id, ch) in chans {
        let mut text = String::new();
        while let Some(t) = ch.recv() {
            text.push_str(&t);
        }
        out.insert(id, text);
    }
    out
}

// --------------------------------------------------------------- scale-up

/// Depth must stay at/above capacity × ADMIT_QUEUE_FACTOR for `up_after`
/// consecutive ticks before a scale-up fires; cooldown then holds.
#[test]
fn scale_up_requires_sustained_depth() {
    let svc = RackService::new(RackSpec::northpole_42u());
    svc.deploy(premium_only_spec()).unwrap();
    let slots = ToyConfig::small().batch_slots;
    let mut scaler = Autoscaler::new(
        svc.clone(),
        vec![ModelScaler::new(
            MODEL,
            CARDS,
            ScalePolicy { up_after: 2, max_instances: 2, cooldown: 2, ..Default::default() },
            premium_only_spec,
        )],
    );

    // depth 10 >= threshold (4 slots x 2), but only one sample: no action
    post_synthetic(&svc, 10, 0);
    assert!(scaler.tick().is_empty(), "one hot sample must not trigger");
    assert_eq!(svc.instance_counts_of(MODEL), (1, 1));

    // second consecutive hot sample: scale-up
    let ev = scaler.tick();
    assert_eq!(ev.len(), 1);
    assert_eq!(ev[0].kind(), "scale_up:deployed");
    assert_eq!(
        ev[0].trigger,
        ScaleTrigger::HotQueue { depth: 10, capacity: slots, ticks: 2 }
    );
    assert_eq!(svc.instance_counts_of(MODEL), (2, 2));
    assert_eq!(svc.capacity_of(MODEL), 2 * slots);

    // cooldown: still hot relative to the old threshold, no second action;
    // and at the doubled capacity the max cap would block it anyway
    assert!(scaler.tick().is_empty());
    assert!(scaler.tick().is_empty());
    assert_eq!(scaler.log().len(), 1);

    drain_synthetic(&svc);
    svc.shutdown_all();
}

// ------------------------------------------------------ overcommit backoff

/// When the pool cannot fit another instance the scaler emits a typed
/// `Overcommit` outcome and backs off (doubling), instead of hammering
/// deploy every tick; freeing cards lets the next qualified tick deploy.
#[test]
fn overcommit_backs_off_then_deploys_once_cards_free() {
    let svc = RackService::new(RackSpec::northpole_42u());
    // 281 blocked + 4 serving = 285 leased; 3 free < 4 wanted
    let blocker = svc
        .deploy(InstanceSpec {
            model: "blocker".into(),
            cards: 281,
            engine: None,
            opts: Default::default(),
            priorities: vec![0],
            max_tokens: 8,
        })
        .unwrap();
    svc.deploy(premium_only_spec()).unwrap();
    let mut scaler = Autoscaler::new(
        svc.clone(),
        vec![ModelScaler::new(
            MODEL,
            CARDS,
            ScalePolicy {
                up_after: 1,
                max_instances: 2,
                cooldown: 0,
                backoff_base: 2,
                backoff_cap: 8,
                ..Default::default()
            },
            premium_only_spec,
        )],
    );
    post_synthetic(&svc, 10, 0);

    // t1: overcommit, 2-tick backoff
    let ev = scaler.tick();
    assert_eq!(ev[0].kind(), "scale_up:overcommit");
    match &ev[0].outcome {
        ScaleOutcome::Overcommit { requested, largest_gap, backoff_ticks } => {
            assert_eq!(*requested, CARDS);
            assert_eq!(*largest_gap, 3);
            assert_eq!(*backoff_ticks, 2);
        }
        o => panic!("expected Overcommit, got {o:?}"),
    }
    // t2, t3: backing off — no deploy attempts, fleet unchanged
    assert!(scaler.tick().is_empty());
    assert!(scaler.tick().is_empty());
    assert_eq!(svc.instance_counts_of(MODEL), (1, 1));
    // t4: re-qualified hot -> overcommit again, backoff doubled to 4
    let ev = scaler.tick();
    match &ev[0].outcome {
        ScaleOutcome::Overcommit { backoff_ticks, .. } => assert_eq!(*backoff_ticks, 4),
        o => panic!("expected doubled Overcommit, got {o:?}"),
    }
    // free the pool mid-backoff; the countdown still runs (t5..t8)...
    svc.teardown(blocker).unwrap();
    for _ in 0..4 {
        assert!(scaler.tick().is_empty());
    }
    // ...then t9 deploys
    let ev = scaler.tick();
    assert_eq!(ev[0].kind(), "scale_up:deployed");
    assert_eq!(svc.instance_counts_of(MODEL), (2, 2));
    let ticks: Vec<u64> = scaler.log().events().iter().map(|e| e.tick).collect();
    assert_eq!(ticks, vec![1, 4, 9], "backoff arithmetic must be exact");

    drain_synthetic(&svc);
    svc.shutdown_all();
}

// ------------------------------------------------------------- hysteresis

/// An oscillating load trace — hot for up_after-1 ticks, then empty, over
/// and over — must never trigger any action: the fleet does not flap.
#[test]
fn oscillating_load_never_flaps() {
    let svc = RackService::new(RackSpec::northpole_42u());
    svc.deploy(premium_only_spec()).unwrap();
    let mut scaler = Autoscaler::new(
        svc.clone(),
        vec![ModelScaler::new(
            MODEL,
            CARDS,
            ScalePolicy {
                up_after: 3,
                down_after: 3,
                min_instances: 1,
                max_instances: 4,
                cooldown: 0,
                ..Default::default()
            },
            premium_only_spec,
        )],
    );
    for cycle in 0..10u64 {
        // two hot ticks (depth 9 >= 8)...
        post_synthetic(&svc, 9, cycle * 100);
        assert!(scaler.tick().is_empty(), "cycle {cycle}");
        assert!(scaler.tick().is_empty(), "cycle {cycle}");
        // ...then the queue empties before the third
        drain_synthetic(&svc);
        assert!(scaler.tick().is_empty(), "cycle {cycle}");
    }
    assert!(scaler.log().is_empty(), "oscillating trace must not flap the fleet");
    assert_eq!(svc.instance_counts_of(MODEL), (1, 1));
    svc.shutdown_all();
}

// --------------------------------------------------- drain before teardown

/// Scale-down is two-phase: mark `ScalingDown` + drain, then tear down
/// only once the drain-completion signal holds — and never below
/// `min_instances`.
#[test]
fn scale_down_drains_then_tears_down_and_respects_min() {
    let svc = RackService::new(RackSpec::northpole_42u());
    let a = svc.deploy(premium_only_spec()).unwrap();
    let b = svc.deploy(premium_only_spec()).unwrap();
    assert!(b > a);
    let slots = ToyConfig::small().batch_slots;
    let mut scaler = Autoscaler::new(
        svc.clone(),
        vec![ModelScaler::new(
            MODEL,
            CARDS,
            ScalePolicy {
                min_instances: 1,
                max_instances: 2,
                up_after: 2,
                down_after: 2,
                cooldown: 0,
                ..Default::default()
            },
            premium_only_spec,
        )],
    );

    // two quiet ticks: the newest instance (b) starts draining
    assert!(scaler.tick().is_empty());
    let ev = scaler.tick();
    assert_eq!(ev.len(), 1);
    assert_eq!(ev[0].kind(), "scale_down:draining");
    assert_eq!(ev[0].action, ScaleAction::ScaleDown { instance: b });
    assert_eq!(
        svc.instances().iter().find(|i| i.id == b).unwrap().state,
        InstanceState::ScalingDown
    );
    assert_eq!(svc.capacity_of(MODEL), slots, "draining instance leaves capacity");
    assert_eq!(svc.instance_counts_of(MODEL), (1, 2));

    // teardown happens only once the drain-completion signal holds; the
    // scaler polls it per tick (no sleeps — spin on the signal here)
    while !svc.drain_complete(b).unwrap() {
        std::thread::yield_now();
    }
    assert_eq!(svc.in_flight_of(MODEL), 0);
    let ev = scaler.tick();
    assert_eq!(ev[0].kind(), "scale_down:torn_down");
    assert_eq!(ev[0].trigger, ScaleTrigger::DrainComplete { instance: b });
    assert_eq!(svc.instance_counts_of(MODEL), (1, 1));
    assert_eq!(svc.inventory().in_use(), CARDS, "victim's cards returned");

    // at min_instances: quiet forever, but never scale below the floor
    for _ in 0..6 {
        scaler.tick();
    }
    assert_eq!(scaler.log().len(), 2, "min_instances floor must hold");
    assert_eq!(svc.instance_counts_of(MODEL), (1, 1));
    svc.shutdown_all();
}

// ----------------------------------------------------------- dead instances

/// A `Serving` instance whose broker workers all died (here: exited on a
/// closed queue — the same signal a panic leaves) serves nothing but
/// still holds cards and counts toward `max_instances`. The scaler must
/// reap it through the two-phase scale-down — with a logged
/// `DeadInstance`-triggered event, not silence — ignoring the
/// `min_instances` floor (a dead instance below the floor serves nothing
/// anyway).
#[test]
fn dead_instances_are_reaped_and_logged() {
    let svc = RackService::new(RackSpec::northpole_42u());
    let ids = vec![
        svc.deploy(premium_only_spec()).unwrap(),
        svc.deploy(premium_only_spec()).unwrap(),
    ];
    let mut scaler = Autoscaler::new(
        svc.clone(),
        vec![ModelScaler::new(
            MODEL,
            CARDS,
            // min == live: the quiet path could never remove these — only
            // the dead-instance reap can
            ScalePolicy { min_instances: 2, max_instances: 2, ..Default::default() },
            premium_only_spec,
        )],
    );

    // kill every worker from the outside; the registry still says Serving
    svc.broker().close(MODEL);
    for &id in &ids {
        let h = svc.instance_handle(id).unwrap();
        while h.has_active_workers() {
            std::thread::yield_now();
        }
    }
    assert_eq!(svc.capacity_of(MODEL), 0);

    // each dead instance is reaped in turn: drain (immediately complete —
    // nothing was in flight) then teardown on the following tick
    for round in 0..2 {
        let ev = scaler.tick();
        assert_eq!(ev.len(), 1, "round {round}");
        assert_eq!(ev[0].kind(), "scale_down:draining", "round {round}");
        assert!(
            matches!(ev[0].trigger, ScaleTrigger::DeadInstance { .. }),
            "round {round}: reap must be attributed to the dead-instance trigger"
        );
        let victim = match &ev[0].action {
            ScaleAction::ScaleDown { instance } => *instance,
            a => panic!("round {round}: unexpected action {a:?}"),
        };
        while !svc.drain_complete(victim).unwrap() {
            std::thread::yield_now();
        }
        let ev = scaler.tick();
        assert_eq!(ev[0].kind(), "scale_down:torn_down", "round {round}");
    }
    assert_eq!(svc.instance_counts_of(MODEL), (0, 0));
    assert_eq!(svc.inventory().in_use(), 0, "reaped cards returned to the pool");
    assert_eq!(
        scaler.log().kinds(),
        vec![
            "scale_down:draining",
            "scale_down:torn_down",
            "scale_down:draining",
            "scale_down:torn_down"
        ]
    );
    svc.shutdown_all();
}

/// After deaths/reaps take the fleet below `min_instances`, the scaler
/// redeploys WITHOUT waiting for queue pressure: a zero-capacity model
/// 503s every request at the front door, so depth alone could never
/// recover it.
#[test]
fn fleet_replenishes_to_min_after_reap() {
    let svc = RackService::new(RackSpec::northpole_42u());
    svc.deploy(premium_only_spec()).unwrap();
    let mut scaler = Autoscaler::new(
        svc.clone(),
        vec![ModelScaler::new(
            MODEL,
            CARDS,
            ScalePolicy { min_instances: 1, max_instances: 2, cooldown: 2, ..Default::default() },
            premium_only_spec,
        )],
    );

    // kill the only worker; the reap takes the fleet to zero
    svc.broker().close(MODEL);
    while svc.dead_instance_of(MODEL).is_none() {
        std::thread::yield_now();
    }
    let ev = scaler.tick();
    assert_eq!(ev[0].kind(), "scale_down:draining");
    let victim = match &ev[0].action {
        ScaleAction::ScaleDown { instance } => *instance,
        a => panic!("unexpected action {a:?}"),
    };
    while !svc.drain_complete(victim).unwrap() {
        std::thread::yield_now();
    }
    let ev = scaler.tick();
    assert_eq!(ev[0].kind(), "scale_down:torn_down");
    assert_eq!(svc.instance_counts_of(MODEL), (0, 0));

    // cooldown (2 ticks), then the floor redeploys with depth still 0
    assert!(scaler.tick().is_empty());
    assert!(scaler.tick().is_empty());
    let ev = scaler.tick();
    assert_eq!(ev[0].kind(), "scale_up:deployed");
    assert!(
        matches!(ev[0].trigger, ScaleTrigger::BelowFloor { serving: 0, min: 1 }),
        "replenish must be attributed to the floor, not queue depth: {:?}",
        ev[0].trigger
    );
    // live only: the replacement subscribed to the still-closed queue, so
    // its worker may already have exited again (serving is racy here —
    // on a live queue it would stay 1)
    assert_eq!(svc.instance_counts_of(MODEL).1, 1, "one live instance redeployed");
    svc.shutdown_all();
}

// -------------------------------------------------------------- soak/chaos

/// Dumps the autoscale event log on drop — success *and* panic — so the
/// CI release job can upload it as an artifact when the soak fails.
struct LogDump(Arc<AutoscaleLog>, PathBuf);

impl Drop for LogDump {
    fn drop(&mut self) {
        let _ = self.0.write_json(&self.1);
    }
}

fn log_path() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("AUTOSCALE_LOG.json")
}

/// The soak (release-only: debug-mode toy serving is too slow to hold a
/// 40-request wave deterministically): a fixed-seed traffic wave against
/// a 2-instance-max policy. Asserts depth-triggered scale-up fires, every
/// admitted request completes byte-identically to a statically
/// provisioned 2-instance fleet, scale-down never tears down an instance
/// with in-flight sequences (two-phase drain), no completion is lost or
/// duplicated, and the event log matches the golden sequence.
#[test]
#[cfg_attr(
    debug_assertions,
    ignore = "release-only soak: run `cargo test --release` (CI tier1-release job)"
)]
fn soak_wave_scales_up_serves_identically_then_scales_down() {
    let prompts: Vec<String> = {
        let mut rng = Rng::seed(0xC0FFEE);
        (0..40)
            .map(|i| {
                let len = rng.usize(1, 12);
                let mut s = format!("p{i}-");
                for _ in 0..len {
                    s.push((b'a' + rng.usize(0, 26) as u8) as char);
                }
                s
            })
            .collect()
    };

    // reference: statically provisioned 2-instance fleet, same wave
    let reference = {
        let svc = RackService::new(RackSpec::northpole_42u());
        svc.deploy(live_spec()).unwrap();
        svc.deploy(live_spec()).unwrap();
        let out = collect(post_wave(&svc, &prompts));
        svc.shutdown_all();
        out
    };
    assert_eq!(reference.len(), prompts.len());
    assert!(reference.values().all(|t| !t.is_empty()), "reference must produce tokens");

    // autoscaled fleet: starts at 1 instance, capped at 2
    let svc = RackService::new(RackSpec::northpole_42u());
    let first_id = svc.deploy(live_spec()).unwrap();
    let mut scaler = Autoscaler::new(
        svc.clone(),
        vec![ModelScaler::new(
            MODEL,
            CARDS,
            ScalePolicy {
                min_instances: 1,
                max_instances: 2,
                up_after: 2,
                down_after: 3,
                cooldown: 2,
                ..Default::default()
            },
            live_spec,
        )],
    );
    let _dump = LogDump(scaler.log(), log_path());

    // ---- phase A: the wave lands; tick until the scale-up fires --------
    let chans = post_wave(&svc, &prompts);
    let mut ramp_ticks = 0;
    while scaler.log().is_empty() {
        scaler.tick();
        ramp_ticks += 1;
        assert!(ramp_ticks <= 4, "scale-up must fire while the wave is still queued");
    }
    let ev = scaler.log().events();
    assert_eq!(ev[0].kind(), "scale_up:deployed", "depth-triggered scale-up");
    let second_id = match &ev[0].outcome {
        ScaleOutcome::Deployed { instance } => *instance,
        o => panic!("expected Deployed, got {o:?}"),
    };
    assert_eq!(svc.instance_counts_of(MODEL), (2, 2));
    let up_tick = ev[0].tick;

    // ---- phase B: no ticking; every admitted request completes ---------
    let out = collect(chans);
    assert_eq!(out, reference, "autoscaled fleet must serve byte-identically");

    // ---- phase C: quiet -> drain -> teardown, exact tick arithmetic ----
    // cooldown (2 ticks), then the 3rd consecutive quiet sample fires the
    // scale-down; the windows were reset at the deploy, so nothing stale
    // can trigger earlier
    assert!(scaler.tick().is_empty(), "cooldown tick 1");
    assert!(scaler.tick().is_empty(), "cooldown tick 2");
    let ev = scaler.tick();
    assert_eq!(ev.len(), 1, "third quiet tick fires the scale-down");
    assert_eq!(ev[0].kind(), "scale_down:draining");
    assert_eq!(ev[0].action, ScaleAction::ScaleDown { instance: second_id });
    assert_eq!(ev[0].tick, up_tick + 3);

    // drain-before-teardown: nothing is in flight, and the teardown tick
    // only fires once the completion signal holds
    while !svc.drain_complete(second_id).unwrap() {
        std::thread::yield_now();
    }
    assert_eq!(svc.in_flight_of(MODEL), 0, "teardown must never race in-flight work");
    let ev = scaler.tick();
    assert_eq!(ev[0].kind(), "scale_down:torn_down");
    assert_eq!(ev[0].tick, up_tick + 4);
    let served_victim = match &ev[0].outcome {
        ScaleOutcome::TornDown { served } => *served,
        o => panic!("expected TornDown, got {o:?}"),
    };

    // ---- golden event log ----------------------------------------------
    assert_eq!(
        scaler.log().kinds(),
        vec!["scale_up:deployed", "scale_down:draining", "scale_down:torn_down"],
        "event log must match the golden sequence"
    );

    // ---- no lost or duplicated completions ------------------------------
    let served_survivor = svc.teardown(first_id).unwrap();
    assert_eq!(
        served_victim + served_survivor,
        prompts.len(),
        "every request served exactly once across scale-up and scale-down"
    );
    assert_eq!(svc.inventory().in_use(), 0);
    svc.shutdown_all();
}
