//! Prefix-cache / KV-reuse tier (ISSUE 8), end to end over the stub
//! backend.
//!
//! The contract under test: a conversation's turn-k prompt reuses the KV
//! rows its turn k-1 left parked in the slot — prefill runs only over the
//! unmatched suffix — and reuse is *invisible* in the output bytes: every
//! stream is identical to a cold full-prefill run. The cache may only
//! ever change latency, never tokens. Stale KV is never served: evicted
//! or invalidated entries fall back loudly to a full prefill, and a dead
//! chain drops every parked entry before replay.
//!
//! The toy model's vocabulary is 32, so prompts are built from bytes
//! `1..=30` — distinct token ids that survive the vocab clamp. Printable
//! ASCII would all clamp to token 31 and every prompt would alias.

use std::sync::Arc;

use npserve::broker::Task;
use npserve::config::hw::RackSpec;
use npserve::fault::FaultPlan;
use npserve::rack::{InstanceSpec, RackService};
use npserve::runtime::testmodel::ToyConfig;
use npserve::service::{
    prefix_route_hash, GenRequest, LlmInstance, PrefixOptions, ServeOptions, SharedEngine,
};
use npserve::tokenizer::ByteTokenizer;

fn toy_engine() -> SharedEngine {
    SharedEngine(Arc::new(ToyConfig::small().engine()))
}

/// A prompt of distinct sub-vocab token ids (see module docs).
fn p(ids: &[u8]) -> String {
    ids.iter().map(|&b| b as char).collect()
}

fn request(id: u64, prompt: &str, n: usize) -> GenRequest {
    GenRequest {
        id,
        prompt: prompt.into(),
        max_tokens: n,
        temperature: 0.0,
        top_k: 0,
        stop_byte: None,
        retries: 0,
        resume_from: 0,
        prefix_hash: 0,
        affinity: false,
        cancel: None,
    }
}

fn gen(inst: &Arc<LlmInstance>, id: u64, prompt: &str, n: usize) -> Vec<u32> {
    inst.submit(request(id, prompt, n));
    inst.serve_until_drained();
    let updates = inst.updates.lock().unwrap();
    let mut toks = Vec::new();
    while let Ok(u) = updates.try_recv() {
        if let npserve::service::GenUpdate::Token { id: uid, token, .. } = u {
            if uid == id {
                toks.push(token);
            }
        }
    }
    toks
}

/// Multi-turn conversation: turn k's prompt extends turn k-1's prompt
/// plus its generated reply, so every warm turn resumes from parked KV.
/// The warm instance must produce byte-identical streams to a cold
/// (prefix-disabled) control, and its counters must account for every
/// reuse exactly.
#[test]
fn multi_turn_reuse_is_byte_identical_and_counted() {
    let warm = LlmInstance::start(toy_engine());
    let cold = LlmInstance::start_with(
        toy_engine(),
        ServeOptions {
            prefix: PrefixOptions { enabled: false, ..Default::default() },
            ..Default::default()
        },
    );
    let t = ByteTokenizer;

    // turn 1: 8 prompt tokens, 4 generated; kv_len 11 parks (last
    // sampled token's KV is never written)
    let mut history = p(&[1, 2, 3, 4, 5, 6, 7, 8]);
    let user_turns: [&[u8]; 3] = [&[], &[9, 10, 11, 12], &[13, 14]];
    for (k, next) in user_turns.iter().enumerate() {
        history.push_str(&p(next));
        let id = 10 + k as u64;
        let w = gen(&warm, id, &history, 4);
        let c = gen(&cold, id, &history, 4);
        assert_eq!(w.len(), 4, "turn {k} truncated");
        assert_eq!(w, c, "turn {k}: reuse changed the output bytes");
        // the assistant reply joins the conversation history
        history.push_str(&t.decode(&w));
    }

    // turn 2 matched 11 tokens chunk-aligned to 8; turn 3 matched 19
    // aligned to 16 (prefill_chunk = 4)
    let s = warm.prefix_counters().snapshot();
    assert_eq!(s.hits, 2, "turns 2 and 3 must both reuse parked KV: {s}");
    assert_eq!(s.misses, 1, "only turn 1 prefills from scratch: {s}");
    assert_eq!(s.matched_tokens, 8 + 16, "chunk-aligned reuse lengths: {s}");
    assert_eq!(s.parked_slots, 1, "only turn 3's retirement stays parked: {s}");
    assert!(s.parked_bytes > 0, "parked gauge must track KV bytes: {s}");
    assert_eq!(warm.parked_prefixes(), 1);

    // the control instance's cache path never ran
    let c = cold.prefix_counters().snapshot();
    assert_eq!((c.hits, c.misses, c.parked_slots), (0, 0, 0), "{c}");

    warm.shutdown();
    cold.shutdown();
}

/// ISSUE 8 satellite: the eviction/routing race. Conversation A's parked
/// KV is displaced (max_parked = 1) by conversation B before A's turn 2
/// arrives — carrying `affinity` + its prefix hash as if routing had
/// already promised it a warm slot. The serve path must fall back to a
/// full cold prefill (counted as a stale route, never a hit) and still
/// produce bytes identical to a never-cached run.
#[test]
fn evicted_prefix_falls_back_to_cold_prefill() {
    let inst = LlmInstance::start_with(
        toy_engine(),
        ServeOptions {
            prefix: PrefixOptions { max_parked: 1, ..Default::default() },
            ..Default::default()
        },
    );
    let a1 = p(&[1, 2, 3, 4, 5, 6, 7, 8]);
    let out_a1 = gen(&inst, 1, &a1, 4);
    assert_eq!(inst.parked_prefixes(), 1);

    // B shares no prefix with A; its retirement displaces A's entry
    let out_b = gen(&inst, 2, &p(&[20, 21, 22, 23, 24, 25, 26, 27]), 4);
    assert_eq!(out_b.len(), 4);
    let s = inst.prefix_counters().snapshot();
    assert_eq!(s.evictions, 1, "max_parked=1 must displace A: {s}");
    assert_eq!(inst.parked_prefixes(), 1, "only B's entry survives");

    // A's turn 2 arrives with a (now stale) affinity promise
    let a2 = format!("{a1}{}{}", ByteTokenizer.decode(&out_a1), p(&[9, 10]));
    let mut req = request(3, &a2, 4);
    req.affinity = true;
    req.prefix_hash = prefix_route_hash(&a2);
    inst.submit(req);
    inst.serve_until_drained();

    let s = inst.prefix_counters().snapshot();
    assert_eq!(s.hits, 0, "no parked prefix matches A's turn 2: {s}");
    assert_eq!(s.stale_routes, 1, "the cold fallback must be loud: {s}");
    assert_eq!(s.misses, 3, "{s}");

    // bytes must match a never-cached control run of the same prompt
    let control = LlmInstance::start_with(
        toy_engine(),
        ServeOptions {
            prefix: PrefixOptions { enabled: false, ..Default::default() },
            ..Default::default()
        },
    );
    let want = gen(&control, 3, &a2, 4);
    let updates = inst.updates.lock().unwrap();
    let mut got = Vec::new();
    while let Ok(u) = updates.try_recv() {
        if let npserve::service::GenUpdate::Token { id: 3, token, .. } = u {
            got.push(token);
        }
    }
    drop(updates);
    assert_eq!(got, want, "stale-route fallback served wrong bytes");
    inst.shutdown();
    control.shutdown();
}

/// An affinity-routed request arriving at an instance that parked nothing
/// (fresh deploy, or full invalidation) is the same race in its purest
/// form: loud stale-route counter, cold prefill, full output.
#[test]
fn affinity_request_on_cold_instance_is_a_stale_route() {
    let inst = LlmInstance::start(toy_engine());
    let prompt = p(&[3, 1, 4, 1, 5]);
    let mut req = request(9, &prompt, 4);
    req.affinity = true;
    req.prefix_hash = prefix_route_hash(&prompt);
    inst.submit(req);
    let recs = inst.serve_until_drained();
    assert_eq!(recs.len(), 1);
    assert_eq!(recs[0].n_out, 4);
    let s = inst.prefix_counters().snapshot();
    assert_eq!((s.hits, s.misses, s.stale_routes), (0, 1, 1), "{s}");
    inst.shutdown();
}

/// Chain death drops every parked entry: KV written by a dead chain must
/// never seed a replay (the survivor re-prefills from the tokens). The
/// parked gauges return to zero and the invalidations counter accounts
/// for each dropped entry.
#[test]
fn chain_death_invalidates_all_parked_kv() {
    // conversations A and B complete on a healthy chain and park their KV;
    // C's long serve then trips the scheduled card death. Wave 1 costs
    // card 0 exactly 10 packets (2 prefill chunks + 3 decode steps per
    // sequence); C alone costs 11 more (4 chunks + 7 steps), so packet 15
    // lands mid-C even if scheduling drift shifts the wave-1 total.
    let plan = FaultPlan::kill_card(0, 15);
    let inst = LlmInstance::start_with(
        toy_engine(),
        ServeOptions { faults: Some(plan.clone()), ..Default::default() },
    );
    inst.submit(request(1, &p(&[1, 2, 3, 4, 5, 6, 7, 8]), 4));
    inst.submit(request(2, &p(&[20, 21, 22, 23, 24, 25, 26, 27]), 4));
    let recs = inst.serve_until_drained();
    assert_eq!(recs.len(), 2, "wave 1 must complete before the fault");
    assert_eq!(inst.parked_prefixes(), 2, "both conversations park");
    let parked_bytes = inst.prefix_counters().snapshot().parked_bytes;
    assert!(parked_bytes > 0);

    inst.submit(request(3, &p(&[11, 12, 13, 14, 15, 16, 17, 18, 11, 12, 13, 14, 15, 16, 17, 18]), 8));
    inst.serve_until_drained();

    assert!(inst.chain_failure().is_some(), "the scheduled fault must fire");
    assert_eq!(plan.injected(), 1);
    let lost = inst.take_lost();
    assert_eq!(lost.len(), 1, "C is captured for requeue, not dropped");
    assert_eq!(lost[0].id, 3);

    let s = inst.prefix_counters().snapshot();
    assert_eq!(inst.parked_prefixes(), 0, "dead-chain KV must not linger");
    assert_eq!(s.invalidations, 2, "both parked entries dropped: {s}");
    assert_eq!(s.parked_slots, 0, "gauge must release on invalidation: {s}");
    assert_eq!(s.parked_bytes, 0, "gauge must release on invalidation: {s}");
    inst.shutdown();
}

// ------------------------------------------------------------- rack level

const MODEL: &str = "toy-testmodel";

/// A roomier toy context so conversations share a ≥32-byte prefix (the
/// route hash's window) while still leaving growth room for later turns.
fn big_engine() -> SharedEngine {
    let mut c = ToyConfig::small();
    c.max_context = 128;
    SharedEngine(Arc::new(c.engine()))
}

fn spec(engine: SharedEngine) -> InstanceSpec {
    let mut spec = InstanceSpec::live(MODEL, 4, engine);
    spec.max_tokens = 8;
    spec
}

/// Post one conversation turn to `queue` (the shared model queue, or an
/// affinity side queue the router steered us to) and collect the stream.
fn ask(svc: &RackService, queue: &str, id: u64, prompt: &str, hash: u64) -> String {
    let ch = svc.broker().post(
        queue,
        Task {
            id,
            priority: 1,
            body: prompt.into(),
            reply_to: id,
            retries: 0,
            resume_from: 0,
            prefix_hash: hash,
            max_tokens: 0,
        },
    );
    let mut text = String::new();
    while let Some(t) = ch.recv() {
        text.push_str(&t);
    }
    text
}

/// Session-affinity routing at the rack level: after turn 1 completes,
/// the rack's prefix router advertises the conversation's route hash on
/// the serving instance's affinity queue; `RackService::route` steers
/// turn 2 there, the instance consumes the side queue first, reuses the
/// parked KV, and the shared fleet counters expose the hit.
#[test]
fn rack_routes_conversation_turns_to_the_parked_instance() {
    let svc = RackService::new(RackSpec::northpole_42u());
    let id = svc.deploy(spec(big_engine())).unwrap();

    // control rack: identical model, prefix tier disabled
    let ctl = RackService::new(RackSpec::northpole_42u());
    let mut cspec = spec(big_engine());
    cspec.opts.prefix.enabled = false;
    ctl.deploy(cspec).unwrap();

    // the conversation's stable head spans the whole 32-byte route window
    let head: Vec<u8> = (1..=30).chain(1..=4).collect();
    let turn1 = p(&head);
    let h1 = prefix_route_hash(&turn1);
    assert!(svc.route(MODEL, h1).is_none(), "nothing advertised yet");

    let w1 = ask(&svc, MODEL, 100, &turn1, h1);
    let c1 = ask(&ctl, MODEL, 100, &turn1, h1);
    assert!(!w1.is_empty());
    assert_eq!(w1, c1, "turn 1 must be cache-neutral");

    // turn 2 extends the same conversation: same first 32 bytes, same hash
    let turn2 = format!("{turn1}{w1}{}", p(&[5, 6, 7]));
    let h2 = prefix_route_hash(&turn2);
    assert_eq!(h2, h1, "route hash must be stable across turns");
    let aff = svc.route(MODEL, h2).expect("turn 1's retirement must advertise");
    assert_eq!(aff, format!("{MODEL}::aff{id}"));

    let w2 = ask(&svc, &aff, 101, &turn2, h2);
    let c2 = ask(&ctl, MODEL, 101, &turn2, h2);
    assert_eq!(w2, c2, "affinity-steered turn 2 changed the bytes");

    // the hit is visible in the rack's shared fleet metrics
    let s = svc.fleet_metrics().prefix;
    assert_eq!(s.hits, 1, "turn 2 must reuse turn 1's parked KV: {s}");
    assert_eq!(s.misses, 1, "{s}");
    assert!(s.matched_tokens >= 32, "the whole head re-prefilled?: {s}");

    // unknown conversations are never steered
    assert!(svc.route(MODEL, prefix_route_hash("unrelated")).is_none());
    // the control rack advertised nothing
    assert!(ctl.route(MODEL, h1).is_none());
    assert_eq!(ctl.fleet_metrics().prefix.hits, 0);

    svc.shutdown_all();
    ctl.shutdown_all();
}
