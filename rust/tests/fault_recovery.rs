//! Card-failure fault domains (ISSUE 7): chain death, watchdog timeout,
//! and lost-sequence recovery, end to end over the stub backend.
//!
//! The contract under test: a card fault costs the fleet one chain, never
//! a sequence. Every in-flight sequence of a dead chain is requeued at the
//! front of its priority class with a bumped retry epoch and replayed
//! deterministically (greedy sampling + replay suppression), so the
//! client's stream is byte-identical to a faultless run — or, past the
//! retry budget, terminated with a typed `recoverable_error` message
//! instead of a hang. Fault counters make every step visible.

use std::collections::BTreeSet;
use std::sync::Arc;
use std::time::{Duration, Instant};

use npserve::broker::Task;
use npserve::config::hw::RackSpec;
use npserve::fault::{FaultEvent, FaultKind, FaultPlan};
use npserve::metrics::FaultSnapshot;
use npserve::npruntime::ChainError;
use npserve::rack::{InstanceSpec, RackService};
use npserve::runtime::testmodel::ToyConfig;
use npserve::service::{GenRequest, LlmInstance, ServeOptions, SharedEngine};

fn toy_engine() -> SharedEngine {
    SharedEngine(Arc::new(ToyConfig::small().engine()))
}

const MODEL: &str = "toy-testmodel";

fn toy_spec() -> InstanceSpec {
    let mut spec = InstanceSpec::live(MODEL, 4, toy_engine());
    // leave room for the whole prompt in the toy's 32-token context
    spec.max_tokens = 8;
    spec
}

type Wave = Vec<(u64, Arc<npserve::broker::ResponseChannel>)>;

fn post_wave(svc: &RackService, prompts: &[String]) -> Wave {
    let broker = svc.broker();
    prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            (
                100 + i as u64,
                broker.post(
                    MODEL,
                    Task {
                        id: i as u64,
                        priority: (i % 3) as u8,
                        body: p.clone(),
                        reply_to: 100 + i as u64,
                        retries: 0,
                        resume_from: 0,
                        prefix_hash: 0,
                        max_tokens: 0,
                    },
                ),
            )
        })
        .collect()
}

fn collect(chans: Wave) -> Vec<(u64, String)> {
    chans
        .into_iter()
        .map(|(id, ch)| {
            let mut text = String::new();
            while let Some(t) = ch.recv() {
                text.push_str(&t);
            }
            (id, text)
        })
        .collect()
}

/// Poll until the instance's chain recorded a fault AND its broker worker
/// exited (the requeue of its lost sequences happens before the exit, so
/// once this returns the broker state is settled).
fn wait_chain_death(svc: &RackService, id: u64) -> ChainError {
    let h = svc.instance_handle(id).expect("instance handle");
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        if let Some(e) = h.chain_failure() {
            if !h.has_active_workers() {
                return e;
            }
        }
        assert!(Instant::now() < deadline, "chain death never observed");
        std::thread::sleep(Duration::from_millis(1));
    }
}

/// Acceptance chaos run: one of two instances is killed mid-wave by a
/// deterministic fault plan; every sequence still completes exactly once,
/// byte-identical to a faultless reference, and the rack's fault counters
/// account for the whole recovery.
#[test]
fn chain_death_mid_wave_loses_no_sequence() {
    let prompts: Vec<String> = (0..12)
        .map(|i| format!("prompt-{i}-{}", "x".repeat(i % 5)))
        .collect();

    // faultless reference: a single healthy instance serves everything
    // (greedy sampling — the same replay determinism recovery relies on)
    let reference = {
        let svc = RackService::new(RackSpec::northpole_42u());
        svc.deploy(toy_spec()).unwrap();
        let out = collect(post_wave(&svc, &prompts));
        svc.shutdown_all();
        out
    };
    assert!(reference.iter().all(|(_, t)| !t.is_empty()));

    // chaos fleet: the wave is queued first, then a victim instance whose
    // card 0 dies on its 6th packet consumes a batch — mid-prefill, with
    // clients already streaming — and a healthy survivor is deployed
    // after the death (the autoscaler's reap/redeploy sequence, driven by
    // hand so the schedule is deterministic).
    let svc = RackService::new(RackSpec::northpole_42u());
    let plan = FaultPlan::kill_card(0, 6);
    let chans = post_wave(&svc, &prompts);

    let mut victim = toy_spec();
    victim.opts.faults = Some(plan.clone());
    let vid = svc.deploy(victim).unwrap();
    let cause = wait_chain_death(&svc, vid);
    assert!(
        matches!(cause, ChainError::CardDead { card: 0, .. }),
        "unexpected death verdict: {cause}"
    );
    assert_eq!(plan.injected(), 1, "exactly the scheduled fault fired");

    // the rack sees the dead instance through the same signal the
    // autoscaler reaps on
    assert_eq!(svc.dead_instance_of(MODEL), Some(vid));

    // the victim's in-flight sequences went back to the broker, not to
    // their clients as truncated streams
    let snap = svc.fault_counters().snapshot();
    assert_eq!(snap.chain_deaths, 1);
    assert!(
        (1..=4).contains(&snap.sequences_requeued),
        "a batch of at most 4 slots was in flight: {snap}"
    );
    assert_eq!(snap.sequences_lost, 0, "retry budget must not be spent: {snap}");
    assert_eq!(
        svc.broker().stats(MODEL).retried,
        snap.sequences_requeued,
        "requeues flow through Broker::requeue"
    );

    // redeploy: a healthy instance drains the queue, requeued tasks first
    let sid = svc.deploy(toy_spec()).unwrap();
    let out = collect(chans);
    assert_eq!(
        out, reference,
        "recovered streams must be byte-identical to the faultless run"
    );

    // the retried sequences completed on the survivor
    let snap = svc.fault_counters().snapshot();
    assert_eq!(snap.sequences_recovered, snap.sequences_requeued, "{snap}");
    assert_eq!(snap.sequences_lost, 0);
    assert_eq!(svc.fleet_metrics().faults, snap, "fleet metrics expose the tally");

    // exactly-once: completions pumped across both instances cover the
    // wave with no duplicates
    let served = svc.teardown(vid).unwrap() + svc.teardown(sid).unwrap();
    assert_eq!(served, prompts.len(), "every sequence completed exactly once");
    assert_eq!(svc.inventory().in_use(), 0);
}

/// Past the retry budget the client gets a typed `recoverable_error`
/// message and a finished stream — never a silent hang. Teardown of each
/// dead instance must leave the requeued task in the broker (the
/// chain-death exception to the last-consumer abandon sweep).
#[test]
fn retry_budget_exhausts_to_a_typed_error() {
    let svc = RackService::new(RackSpec::northpole_42u());
    let chans = post_wave(&svc, &["doomed".to_string()]);

    // MAX_SEQ_RETRIES = 3: deaths at retries 0, 1 and 2 requeue; the
    // fourth chain death gives up.
    for round in 0..4 {
        let mut spec = toy_spec();
        spec.opts.faults = Some(FaultPlan::kill_card(0, 1));
        let vid = svc.deploy(spec).unwrap();
        wait_chain_death(&svc, vid);
        // the reap: requeued work must survive losing its last consumer
        svc.teardown(vid).unwrap();
        let snap = svc.fault_counters().snapshot();
        assert_eq!(snap.chain_deaths, round + 1);
    }

    let out = collect(chans);
    assert_eq!(out.len(), 1);
    let text = &out[0].1;
    assert!(
        text.starts_with("recoverable_error: "),
        "client must see a typed failure, got {text:?}"
    );
    assert!(text.contains("gave up after 3 retries"), "{text:?}");

    let expect = FaultSnapshot {
        chain_deaths: 4,
        packet_timeouts: 0,
        bad_frames: 0,
        sequences_requeued: 3,
        sequences_recovered: 0,
        sequences_lost: 1,
    };
    assert_eq!(svc.fault_counters().snapshot(), expect);
    svc.shutdown_all();
}

/// A dropped frame produces no completion and no chain-level error — only
/// the armed per-packet deadline can catch it. The watchdog's timeout
/// verdict kills the chain and the instance captures every owned sequence.
#[test]
fn watchdog_catches_a_silent_frame_drop() {
    let opts = ServeOptions {
        packet_deadline: Some(Duration::from_millis(80)),
        faults: Some(FaultPlan::new(vec![FaultEvent {
            card: 0,
            at_packet: 2,
            kind: FaultKind::DropFrame,
        }])),
        ..ServeOptions::default()
    };
    let inst = LlmInstance::start_with(toy_engine(), opts);
    for id in [1u64, 2] {
        inst.submit(GenRequest {
            id,
            prompt: format!("drop-{id}"),
            max_tokens: 4,
            temperature: 0.0,
            top_k: 0,
            stop_byte: None,
            retries: 0,
            resume_from: 0,
            prefix_hash: 0,
            affinity: false,
            cancel: None,
        });
    }
    let records = inst.serve_until_drained();

    match inst.chain_failure() {
        Some(ChainError::PacketTimeout { waited_ms, .. }) => {
            assert!(waited_ms >= 80, "deadline fired early: {waited_ms} ms")
        }
        other => panic!("expected PacketTimeout, got {other:?}"),
    }
    let snap = inst.fault_counters().snapshot();
    assert_eq!(snap.chain_deaths, 1);
    assert_eq!(snap.packet_timeouts, 1);

    // exactly-once accounting: completed ∪ captured covers both
    // sequences with no overlap, and nothing is left in flight
    let lost = inst.take_lost();
    assert!(!lost.is_empty(), "the dropped packet's sequence must be captured");
    let completed: BTreeSet<u64> = records.iter().map(|r| r.id as u64).collect();
    let captured: BTreeSet<u64> = lost.iter().map(|l| l.id).collect();
    assert!(completed.is_disjoint(&captured), "{completed:?} vs {captured:?}");
    let mut all = completed;
    all.extend(&captured);
    assert_eq!(all, BTreeSet::from([1, 2]));
    assert_eq!(inst.in_flight(), 0, "captures must release in-flight holds");
    inst.shutdown();
}

/// Seeded packet-loss fuzz (ISSUE 7 satellite): random die/stall/drop/
/// corrupt schedules must never deadlock the serving loop, leak an
/// in-flight hold, or double-account a sequence — every submitted id ends
/// either completed or captured, exactly once, within the watchdog bound.
#[test]
fn seeded_fault_fuzz_accounts_for_every_sequence() {
    for seed in 0..12u64 {
        let opts = ServeOptions {
            packet_deadline: Some(Duration::from_millis(100)),
            faults: Some(FaultPlan::seeded(seed, 4, 40, 3)),
            ..ServeOptions::default()
        };
        let inst = LlmInstance::start_with(toy_engine(), opts);
        let ids: BTreeSet<u64> = (1..=4).collect();
        for &id in &ids {
            inst.submit(GenRequest {
                id,
                prompt: format!("fuzz-{seed}-{id}"),
                max_tokens: 6,
                temperature: 0.0,
                top_k: 0,
                stop_byte: None,
                retries: 0,
                resume_from: 0,
                prefix_hash: 0,
                affinity: false,
                cancel: None,
            });
        }
        let records = inst.serve_until_drained();
        let lost = inst.take_lost();

        let completed: BTreeSet<u64> = records.iter().map(|r| r.id as u64).collect();
        let captured: BTreeSet<u64> = lost.iter().map(|l| l.id).collect();
        assert!(
            completed.is_disjoint(&captured),
            "seed {seed}: double-accounted ids {:?}",
            completed.intersection(&captured).collect::<Vec<_>>()
        );
        let mut all = completed.clone();
        all.extend(&captured);
        assert_eq!(all, ids, "seed {seed}: sequences vanished or were invented");
        assert_eq!(inst.in_flight(), 0, "seed {seed}: in-flight hold leaked");
        let snap = inst.fault_counters().snapshot();
        assert!(snap.chain_deaths <= 1, "seed {seed}: one run, one death: {snap}");
        if !captured.is_empty() {
            assert_eq!(
                snap.chain_deaths, 1,
                "seed {seed}: captures require a recorded chain death"
            );
        }
        inst.shutdown();
    }
}
