//! Integration: the full §IV service path over real PJRT numerics.
//!
//! Requires `make artifacts` (artifacts/granite-test). The key invariants:
//! determinism, slot isolation under dynamic batching, broker round-trip,
//! and agreement between batched and solo generation.

use std::path::PathBuf;
use std::sync::Arc;

use npserve::broker::{Broker, Task};
use npserve::runtime::Engine;
use npserve::service::{GenRequest, LlmInstance, SharedEngine};

fn engine() -> Option<SharedEngine> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/granite-test");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(SharedEngine(Arc::new(Engine::load(&dir).unwrap())))
}

fn gen(inst: &Arc<LlmInstance>, id: u64, prompt: &str, n: usize) -> Vec<u32> {
    inst.submit(GenRequest {
        id,
        prompt: prompt.into(),
        max_tokens: n,
        temperature: 0.0,
        top_k: 0,
        stop_byte: None,
        retries: 0,
        resume_from: 0,
        prefix_hash: 0,
        affinity: false,
        cancel: None,
    });
    inst.serve_until_drained();
    let updates = inst.updates.lock().unwrap();
    let mut toks = Vec::new();
    while let Ok(u) = updates.try_recv() {
        if let npserve::service::GenUpdate::Token { id: uid, token, .. } = u {
            if uid == id {
                toks.push(token);
            }
        }
    }
    toks
}

#[test]
fn greedy_generation_is_deterministic() {
    let Some(e) = engine() else { return };
    let inst = LlmInstance::start(e);
    let a = gen(&inst, 1, "ab", 6);
    let b = gen(&inst, 2, "ab", 6);
    assert_eq!(a.len(), 6);
    assert_eq!(a, b, "same prompt after cache reuse must regenerate identically");
}

#[test]
fn batched_generation_matches_solo() {
    let Some(e) = engine() else { return };
    // solo instance runs each prompt alone; batch instance serves them
    // simultaneously in different slots — outputs must agree exactly
    // (slot isolation + correct per-slot positions).
    let solo = LlmInstance::start(e.clone());
    let s1 = gen(&solo, 1, "abc", 5);
    let s2 = gen(&solo, 2, "xyz9", 5);

    let batch = LlmInstance::start(e);
    batch.submit(GenRequest {
        id: 11, prompt: "abc".into(), max_tokens: 5,
        temperature: 0.0, top_k: 0, stop_byte: None,
        retries: 0,
        resume_from: 0,
        prefix_hash: 0,
        affinity: false,
        cancel: None,
    });
    batch.submit(GenRequest {
        id: 12, prompt: "xyz9".into(), max_tokens: 5,
        temperature: 0.0, top_k: 0, stop_byte: None,
        retries: 0,
        resume_from: 0,
        prefix_hash: 0,
        affinity: false,
        cancel: None,
    });
    batch.serve_until_drained();
    let updates = batch.updates.lock().unwrap();
    let (mut b1, mut b2) = (Vec::new(), Vec::new());
    while let Ok(u) = updates.try_recv() {
        if let npserve::service::GenUpdate::Token { id, token, .. } = u {
            if id == 11 { b1.push(token) } else if id == 12 { b2.push(token) }
        }
    }
    assert_eq!(b1, s1, "slot 0 diverged under batching");
    assert_eq!(b2, s2, "slot 1 diverged under batching");
}

#[test]
fn more_requests_than_slots_all_complete() {
    let Some(e) = engine() else { return };
    let inst = LlmInstance::start(e);
    let b = inst.manifest().batch_slots;
    let n_reqs = b * 2 + 1;
    for i in 0..n_reqs {
        inst.submit(GenRequest {
            id: 100 + i as u64,
            prompt: format!("p{i}"),
            max_tokens: 3,
            temperature: 0.0,
            top_k: 0,
            stop_byte: None,
            retries: 0,
            resume_from: 0,
            prefix_hash: 0,
            affinity: false,
            cancel: None,
        });
    }
    let recs = inst.serve_until_drained();
    let done: Vec<_> = recs.iter().filter(|r| r.id >= 100).collect();
    assert_eq!(done.len(), n_reqs, "every request must be served");
    for r in done {
        assert_eq!(r.n_out, 3);
        assert!(r.t_first >= r.t_start);
    }
}

#[test]
fn broker_roundtrip_streams_tokens() {
    let Some(e) = engine() else { return };
    let inst = LlmInstance::start(e);
    let broker = Broker::new();
    let ch = broker.post(
        "granite-test",
        Task { id: 1, priority: 1, body: "3+4=".into(), reply_to: 71, retries: 0, resume_from: 0, prefix_hash: 0, max_tokens: 0 },
    );
    let handle = inst.serve_broker(broker.clone(), "granite-test", vec![0, 1, 2], 4);
    let mut got = Vec::new();
    while let Some(tok) = ch.recv() {
        got.push(tok);
    }
    assert!(!got.is_empty(), "no tokens streamed");
    broker.close("granite-test");
    let served = handle.join().unwrap();
    assert_eq!(served, 1);
}

#[test]
fn long_prompt_spans_multiple_prefill_chunks() {
    let Some(e) = engine() else { return };
    let inst = LlmInstance::start(e);
    let m = inst.manifest();
    // prompt longer than one chunk exercises chunked prefill + final-row
    // extraction
    let prompt = "a".repeat(m.prefill_chunk * 2 + 3);
    let toks = gen(&inst, 5, &prompt, 4);
    assert_eq!(toks.len(), 4);
}

// ---------------------------------------------------------------------
// Stub-backend serving (runtime::testmodel): no PJRT artifacts needed,
// so these run in every CI pass. They pin the zero-copy datapath
// end-to-end: resident (donated) KV caches must generate exactly the
// same tokens as the host round-trip baseline through the full
// broker-to-head card chain.

mod stub_backend {
    use super::gen;
    use npserve::runtime::testmodel::ToyConfig;
    use npserve::service::{GenRequest, LlmInstance, ServeOptions, SharedEngine};
    use std::sync::Arc;

    fn stub_engine() -> SharedEngine {
        SharedEngine(Arc::new(ToyConfig::small().engine()))
    }

    #[test]
    fn serves_without_artifacts_and_is_deterministic() {
        let inst = LlmInstance::start(stub_engine());
        let a = gen(&inst, 1, "hello", 6);
        let b = gen(&inst, 2, "hello", 6);
        assert_eq!(a.len(), 6);
        assert_eq!(a, b, "greedy generation must be deterministic");
    }

    #[test]
    fn resident_kv_generates_identical_tokens_to_host_kv() {
        let resident = LlmInstance::start_with(stub_engine(), ServeOptions::default());
        let host = LlmInstance::start_with(
            stub_engine(),
            ServeOptions { resident_kv: false, ..Default::default() },
        );
        for (id, prompt) in [(1u64, "abc"), (2, "a longer prompt spanning chunks")] {
            let t_res = gen(&resident, id, prompt, 8);
            let t_host = gen(&host, id, prompt, 8);
            assert_eq!(t_res.len(), 8);
            assert_eq!(t_res, t_host, "resident KV diverged on {prompt:?}");
        }
    }

    #[test]
    fn stub_backend_batches_more_requests_than_slots() {
        let inst = LlmInstance::start(stub_engine());
        let b = inst.manifest().batch_slots;
        let n_reqs = b * 2 + 1;
        for i in 0..n_reqs {
            inst.submit(GenRequest {
                id: 100 + i as u64,
                prompt: format!("p{i}"),
                max_tokens: 3,
                temperature: 0.0,
                top_k: 0,
                stop_byte: None,
                retries: 0,
                resume_from: 0,
                prefix_hash: 0,
                affinity: false,
                cancel: None,
            });
        }
        let recs = inst.serve_until_drained();
        assert_eq!(recs.len(), n_reqs, "every request must be served");
        for r in &recs {
            assert_eq!(r.n_out, 3);
        }
    }
}
