//! Integration: the rebuilt front door over a live toy rack (ISSUE 10).
//!
//! Regression coverage for the client-contract sweep, end to end through
//! real sockets — API server → broker → instance → SSE back out:
//!
//! - `max_tokens` is honored: the seed parsed it and then dropped it on
//!   the floor (every request ran to the server-side cap), so a client
//!   asking for 3 tokens got 8. The toy vocab (32 symbols) never emits
//!   the stop byte, so the count is deterministic.
//! - a client vanishing mid-stream cancels generation: the instance
//!   retires the slot early and fleet in-flight returns to 0 — abandoned
//!   streams must not leak decode capacity.

use std::sync::Arc;
use std::time::{Duration, Instant};

use npserve::api::loadgen::{self, LoadSpec};
use npserve::api::{ApiOptions, ApiServer, ServerOptions};
use npserve::config::hw::RackSpec;
use npserve::rack::{InstanceSpec, RackService};
use npserve::runtime::testmodel::ToyConfig;
use npserve::service::SharedEngine;

const MODEL: &str = "toy-testmodel";

fn rack(cfg: ToyConfig, server_max_tokens: usize) -> (Arc<RackService>, ApiServer) {
    let svc = RackService::new(RackSpec::northpole_42u());
    let mut spec = InstanceSpec::live(MODEL, 16, SharedEngine(Arc::new(cfg.engine())));
    spec.max_tokens = server_max_tokens;
    svc.deploy(spec).unwrap();
    let opts = ApiOptions {
        server: ServerOptions {
            counters: svc.front_door_counters().clone(),
            ..ServerOptions::default()
        },
        ..ApiOptions::default()
    };
    let api = ApiServer::serve_with(
        "127.0.0.1:0",
        svc.broker().clone(),
        svc.admission(),
        svc.affinity(),
        opts,
    )
    .unwrap();
    (svc, api)
}

fn await_drained(svc: &Arc<RackService>) {
    let t0 = Instant::now();
    while svc.in_flight_of(MODEL) > 0 {
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "fleet in-flight stuck at {}",
            svc.in_flight_of(MODEL)
        );
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// The bug this regresses: `parse_chat_request` read `max_tokens` but the
/// posted `Task` never carried it, so generation always ran to the
/// server-side default (8 here). Now a request for 3 tokens streams
/// exactly 3 content events.
#[test]
fn client_max_tokens_is_honored_end_to_end() {
    let mut cfg = ToyConfig::small();
    cfg.batch_slots = 4;
    let (svc, api) = rack(cfg, 8);
    let report = loadgen::run(&LoadSpec {
        addr: api.addr().to_string(),
        model: MODEL.into(),
        n_requests: 3,
        rate_per_s: 200.0,
        seed: 9,
        prompt_bytes: (8, 12),
        max_tokens: (3, 3),
        stream: true,
        io_timeout: Duration::from_secs(20),
        ..LoadSpec::default()
    });
    assert_eq!(report.errors(), 0, "{:?}", report.outcomes);
    assert_eq!(report.count_status(200), 3);
    for o in &report.outcomes {
        assert_eq!(
            o.tokens, 3,
            "asked for exactly 3 tokens, streamed {}: {o:?}",
            o.tokens
        );
    }
    await_drained(&svc);
    svc.shutdown_all();
}

/// Mid-stream client disconnect: the SSE writer hits a broken pipe,
/// cancels the response channel, and the instance retires the slot early
/// instead of decoding the remaining tokens for nobody.
#[test]
fn mid_stream_disconnect_releases_the_slot() {
    let mut cfg = ToyConfig::small();
    cfg.batch_slots = 4;
    // pace tokens to ~4 ms so the disconnect lands mid-generation
    cfg.row_work_ns = 300_000;
    let (svc, api) = rack(cfg, 16);
    let report = loadgen::run(&LoadSpec {
        addr: api.addr().to_string(),
        model: MODEL.into(),
        n_requests: 2,
        rate_per_s: 200.0,
        seed: 13,
        prompt_bytes: (8, 12),
        max_tokens: (16, 16),
        stream: true,
        io_timeout: Duration::from_secs(20),
        disconnect_after: Some(1),
        ..LoadSpec::default()
    });
    for o in &report.outcomes {
        assert!(o.disconnected, "{o:?}");
    }
    // the released slots are the assertion: a leak wedges this forever
    await_drained(&svc);
    assert!(
        svc.front_door_counters().snapshot().disconnects >= 1,
        "server never noticed the dead clients"
    );
    svc.shutdown_all();
}
