//! Rack-scale multi-instance serving over the stub backend
//! (`runtime::testmodel`) — no PJRT artifacts needed, so these run in every
//! CI pass.
//!
//! The key invariants (ISSUE 3): several instances lease cards from one
//! shared inventory and consume one model queue; per-request responses
//! route back to the correct caller; instances share no KV state (outputs
//! are byte-identical to a single-instance fleet); drain/teardown of one
//! instance neither closes the model queue nor strands its cards.

use std::collections::BTreeMap;
use std::sync::Arc;

use npserve::broker::Task;
use npserve::config::hw::RackSpec;
use npserve::rack::{deploy_paper_config, InstanceSpec, InstanceState, PaperConfig, RackService};
use npserve::runtime::testmodel::ToyConfig;
use npserve::service::SharedEngine;

fn toy_engine() -> SharedEngine {
    SharedEngine(Arc::new(ToyConfig::small().engine()))
}

const MODEL: &str = "toy-testmodel";

fn deploy_toys(svc: &RackService, n: usize) -> Vec<u64> {
    (0..n)
        .map(|_| {
            let mut spec = InstanceSpec::live(MODEL, 4, toy_engine());
            // leave room for the whole prompt in the toy's 32-token
            // context (admission truncates prompts to ctx - max_tokens - 1)
            spec.max_tokens = 8;
            svc.deploy(spec).expect("toy instance placement")
        })
        .collect()
}

/// Post `prompts` to the model queue (reply_to = 100 + index) and collect
/// each caller's streamed text to completion.
fn roundtrip(svc: &RackService, prompts: &[String]) -> BTreeMap<u64, String> {
    let broker = svc.broker().clone();
    let chans: Vec<_> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            (
                100 + i as u64,
                broker.post(
                    MODEL,
                    Task {
                        id: i as u64,
                        priority: (i % 3) as u8,
                        body: p.clone(),
                        reply_to: 100 + i as u64,
                        retries: 0,
                        resume_from: 0,
                        prefix_hash: 0,
                        max_tokens: 0,
                    },
                ),
            )
        })
        .collect();
    let mut out = BTreeMap::new();
    for (id, ch) in chans {
        let mut text = String::new();
        while let Some(t) = ch.recv() {
            text.push_str(&t);
        }
        out.insert(id, text);
    }
    out
}

#[test]
fn two_instances_share_one_queue_without_kv_contamination() {
    let prompts: Vec<String> = (0..10)
        .map(|i| format!("prompt-{i}-{}", "x".repeat(i % 5)))
        .collect();

    // reference fleet: a single instance serves everything
    let reference = {
        let svc = RackService::new(RackSpec::northpole_42u());
        deploy_toys(&svc, 1);
        let out = roundtrip(&svc, &prompts);
        svc.shutdown_all();
        out
    };
    assert_eq!(reference.len(), prompts.len());
    assert!(
        reference.values().all(|t| !t.is_empty()),
        "reference outputs must be non-empty"
    );
    // distinct prompts should not alias to one output (value-dependent toy)
    let distinct: std::collections::BTreeSet<&String> = reference.values().collect();
    assert!(distinct.len() > 1, "toy outputs unexpectedly collapsed");

    // 2-instance fleet, same broker queue, interleaved requests: every
    // caller must get exactly the output of its own prompt. Any
    // cross-instance KV bleed, wrong-slot write, or misrouted response
    // changes some caller's bytes.
    let svc = RackService::new(RackSpec::northpole_42u());
    let ids = deploy_toys(&svc, 2);
    assert_eq!(svc.inventory().in_use(), 8);
    assert_eq!(svc.capacity_of(MODEL), 2 * ToyConfig::small().batch_slots);
    let out = roundtrip(&svc, &prompts);
    assert_eq!(out, reference, "2-instance fleet diverged from single instance");

    // both instances stay registered and serving until teardown
    let states: Vec<InstanceState> = svc.instances().iter().map(|i| i.state).collect();
    assert_eq!(states, vec![InstanceState::Serving; 2]);
    let served: usize = ids.iter().map(|&id| svc.teardown(id).unwrap()).sum();
    assert_eq!(served, prompts.len(), "every task served exactly once");
    assert_eq!(svc.inventory().in_use(), 0);
}

#[test]
fn drain_and_teardown_keep_the_model_queue_live() {
    let svc = RackService::new(RackSpec::northpole_42u());
    let ids = deploy_toys(&svc, 2);
    let prompts: Vec<String> = (0..4).map(|i| format!("a{i}")).collect();
    let first = roundtrip(&svc, &prompts);
    assert_eq!(first.len(), 4);

    // drain + tear down one instance: its cards return to the pool and the
    // queue must stay open for the survivor
    svc.drain(ids[0]).unwrap();
    svc.teardown(ids[0]).unwrap();
    assert_eq!(svc.inventory().in_use(), 4);
    assert!(!svc.broker().is_closed(MODEL), "teardown must not close a shared queue");
    assert_eq!(svc.capacity_of(MODEL), ToyConfig::small().batch_slots);

    let second = roundtrip(&svc, &prompts);
    assert_eq!(second, first, "survivor instance must serve identically");

    // the freed cards are leasable again
    let id3 = svc.deploy(InstanceSpec::live(MODEL, 4, toy_engine())).unwrap();
    assert_eq!(svc.inventory().in_use(), 8);
    svc.teardown(id3).unwrap();
    svc.shutdown_all();
}

/// Acceptance (ISSUE 3): the 3×8B paper configuration comes up live — real
/// 84-card leases per the paper mapping, numerics on the testmodel backend
/// — serves traffic through the shared model queue, and reports fleet
/// metrics. (The 18×3B path is the same code with a different mapping; the
/// 70B is placement-level, covered by the rack module's unit tests.)
#[test]
fn paper_3x8b_runs_live_on_the_testmodel_backend() {
    let svc = RackService::new(RackSpec::northpole_42u());
    let cfg = PaperConfig::ThreeGranite8b;
    let ids = deploy_paper_config(&svc, cfg, |_| {
        Some(SharedEngine(Arc::new(ToyConfig::small().engine())))
    })
    .expect("3x8b must deploy live");
    assert_eq!(ids.len(), 3);
    assert_eq!(svc.inventory().in_use(), 3 * 84, "paper card counts leased");
    assert_eq!(
        svc.admit(cfg.model()),
        npserve::api::AdmitDecision::Accept,
        "live paper model must be admitted"
    );

    // traffic through the model-named queue, load-balanced by the 3-member
    // consumer group
    let broker = svc.broker().clone();
    let n: u64 = 9;
    let chans: Vec<_> = (0..n)
        .map(|i| {
            broker.post(
                cfg.model(),
                Task {
                    id: i,
                    priority: (i % 3) as u8,
                    body: format!("q{i}"),
                    reply_to: 700 + i,
                    retries: 0,
                    resume_from: 0,
                    prefix_hash: 0,
                    max_tokens: 0,
                },
            )
        })
        .collect();
    for ch in &chans {
        let mut toks = 0;
        while ch.recv().is_some() {
            toks += 1;
        }
        assert!(toks > 0, "every caller must receive tokens");
    }
    let fleet = svc.fleet_metrics();
    assert_eq!(fleet.n_seqs(), n as usize);
    assert!(fleet.otps() > 0.0);
    assert_eq!(fleet.cards_leased, 3 * 84);
    svc.shutdown_all();
    assert_eq!(svc.inventory().in_use(), 0);
}

#[test]
fn admission_tracks_capacity_and_unknown_models() {
    use npserve::api::AdmitDecision;
    let svc = RackService::new(RackSpec::northpole_42u());
    assert_eq!(svc.admit(MODEL), AdmitDecision::UnknownModel);
    let ids = deploy_toys(&svc, 1);
    assert_eq!(svc.admit(MODEL), AdmitDecision::Accept);
    assert_eq!(svc.admit("some-other-model"), AdmitDecision::UnknownModel);

    // a model whose only instance is draining has zero serving capacity:
    // saturated (503, retryable) rather than unknown (404)
    svc.drain(ids[0]).unwrap();
    assert_eq!(svc.admit(MODEL), AdmitDecision::Saturated);
    svc.shutdown_all();
}

/// Regression (ISSUE 5 satellite): admission capacity must come from
/// instances that are *actually* serving. A drain requested directly on
/// the `LlmInstance` (bypassing `RackService::drain`, so the registry
/// state still reads `Serving`) used to keep the instance's slots in the
/// capacity sum — the front door kept admitting work that then queued
/// behind nobody.
#[test]
fn admission_excludes_directly_drained_instances() {
    use npserve::api::AdmitDecision;
    let svc = RackService::new(RackSpec::northpole_42u());
    let ids = deploy_toys(&svc, 2);
    let slots = ToyConfig::small().batch_slots;
    assert_eq!(svc.capacity_of(MODEL), 2 * slots);

    // drain one instance behind the registry's back
    svc.instance_handle(ids[0]).unwrap().request_drain();
    assert_eq!(
        svc.capacity_of(MODEL),
        slots,
        "a directly-drained instance must not count as capacity"
    );
    // the registry still says Serving — the instance flag is the truth
    assert_eq!(
        svc.instances().iter().find(|i| i.id == ids[0]).unwrap().state,
        InstanceState::Serving
    );
    // the survivor keeps the model admittable...
    assert_eq!(svc.admit(MODEL), AdmitDecision::Accept);
    // ...but once it too is drained directly, capacity is 0 and the door
    // saturates instead of queueing work behind nobody
    svc.instance_handle(ids[1]).unwrap().request_drain();
    assert_eq!(svc.capacity_of(MODEL), 0);
    assert_eq!(svc.admit(MODEL), AdmitDecision::Saturated);
    svc.shutdown_all();
}

/// ISSUE 5: an instance whose only broker worker died — here: exited on
/// a closed queue, the same signal a panicked worker leaves — contributes
/// no serving capacity, even though the registry still reads `Serving`
/// and no drain was ever requested. Without the `has_active_workers`
/// check, admission would keep accepting work that queues behind nobody.
#[test]
fn admission_excludes_instances_with_dead_workers() {
    use npserve::api::AdmitDecision;
    let svc = RackService::new(RackSpec::northpole_42u());
    let ids = deploy_toys(&svc, 1);
    assert_eq!(svc.capacity_of(MODEL), ToyConfig::small().batch_slots);

    // kill the consumer from the outside: closing the queue makes the
    // worker exit with the registry none the wiser
    svc.broker().close(MODEL);
    let h = svc.instance_handle(ids[0]).unwrap();
    while h.has_active_workers() {
        std::thread::yield_now();
    }
    assert_eq!(
        svc.instances().iter().find(|i| i.id == ids[0]).unwrap().state,
        InstanceState::Serving,
        "registry state alone cannot see the dead worker"
    );
    assert_eq!(svc.capacity_of(MODEL), 0, "dead-worker instance must not count");
    assert_eq!(svc.admit(MODEL), AdmitDecision::Saturated);
    svc.shutdown_all();
}

/// ISSUE 5: `scale_down` marks the autoscaler's intent (`ScalingDown`),
/// excludes the instance from capacity, and `drain_complete` flips only
/// once the worker exited with nothing in flight — the teardown gate.
#[test]
fn scale_down_marks_state_and_drain_completes() {
    let svc = RackService::new(RackSpec::northpole_42u());
    let ids = deploy_toys(&svc, 2);
    let slots = ToyConfig::small().batch_slots;

    // serve something first so the drained instance had real work
    let first = roundtrip(&svc, &["hello".to_string(), "world".to_string()]);
    assert_eq!(first.len(), 2);

    svc.scale_down(ids[1]).unwrap();
    assert_eq!(
        svc.instances().iter().find(|i| i.id == ids[1]).unwrap().state,
        InstanceState::ScalingDown
    );
    assert_eq!(svc.capacity_of(MODEL), slots, "scaling-down excluded from capacity");
    assert_eq!(svc.instance_counts_of(MODEL), (1, 2), "serving=1, live=2");

    // drain completion: the worker observes the flag at its next bounded
    // wait and exits; poll without sleeping
    while !svc.drain_complete(ids[1]).unwrap() {
        std::thread::yield_now();
    }
    assert_eq!(svc.in_flight_of(MODEL), 0);
    svc.teardown(ids[1]).unwrap();
    assert_eq!(svc.inventory().in_use(), 4, "cards returned");

    // the survivor still serves identically
    let again = roundtrip(&svc, &["hello".to_string(), "world".to_string()]);
    assert_eq!(again, first);
    svc.shutdown_all();
}
