//! Cross-module integration: mapper × sim × metrics × power, plus
//! property-based checks over the whole planning/simulation path.

use npserve::chip::timing::PassKind;
use npserve::config::hw::RackSpec;
use npserve::config::models::{find_model, model_zoo};
use npserve::mapper::map_model;
use npserve::metrics::BatchMetrics;
use npserve::pipeline::schedule::bubble_fraction;
use npserve::pipeline::sim::{simulate, SimConfig};
use npserve::power::deployment_power;
use npserve::util::check::prop_check;
use npserve::prop_assert;

#[test]
fn whole_rack_story_8b() {
    // The paper's headline claim, end to end: 3 instances x 28 users at
    // 2k ctx on one rack, ~2.8 ms ITL, ~30 kW.
    let rack = RackSpec::northpole_42u();
    let m = find_model("granite-3.3-8b").unwrap();
    let mapping = map_model(&m, 28, 2048, &rack).unwrap();
    assert_eq!(mapping.instances_per_rack(&rack), 3);

    let rep = simulate(&mapping, &rack, SimConfig {
        users: 28, prompt_len: 64, gen_len: 64, requests: 28, chunk: 64,
    });
    let met = BatchMetrics::from_records(&rep.seqs);
    assert!((1.8e-3..4.0e-3).contains(&met.itl.mean()), "itl {}", met.itl.mean());

    // one instance = 6 nodes, 84 cards; the rack runs 3
    let p = deployment_power(&rack, 18, 3 * mapping.n_cards(), 1.0);
    assert!(p.total_w < rack.power_budget_w);
    assert!((p.total_w - 30_000.0).abs() < 1500.0, "power {}", p.total_w);

    // per-user throughput: ~10k tok/s aggregate over 3 instances at 28
    // users each -> 30k tok/s rack (abstract: "up to 30,000 tokens/second")
    let rack_otps = 3.0 * met.otps;
    assert!(rack_otps > 20_000.0, "rack otps {rack_otps}");
}

#[test]
fn sim_conserves_tokens_property() {
    let rack = RackSpec::northpole_42u();
    let m = find_model("granite-3.1-3b").unwrap();
    let mapping = map_model(&m, 28, 2048, &rack).unwrap();
    prop_check("sim-conserves-tokens", 8, |r| {
        let users = r.usize(1, 9) as u32;
        let gen = r.usize(2, 18) as u32;
        let reqs = r.usize(1, 12) as u32;
        let rep = simulate(&mapping, &rack, SimConfig {
            users, prompt_len: 32, gen_len: gen, requests: reqs, chunk: 32,
        });
        prop_assert!(rep.seqs.len() == reqs as usize,
                     "served {} of {}", rep.seqs.len(), reqs);
        for s in &rep.seqs {
            prop_assert!(s.n_out == gen, "seq {} produced {}", s.id, s.n_out);
            prop_assert!(s.t_first >= s.t_start, "causality");
            prop_assert!(s.t_end + 1e-12 >= s.t_first, "ordering");
        }
        Ok(())
    });
}

#[test]
fn mapping_invariants_property() {
    let rack = RackSpec::northpole_42u();
    let chip = rack.node.card.chip;
    prop_check("mapping-invariants", 24, |r| {
        let zoo = model_zoo();
        let m = &zoo[r.usize(0, zoo.len())];
        let users = r.usize(1, 30) as u32;
        let ctx = [512u32, 1024, 2048, 4096][r.usize(0, 4)];
        let Ok(map) = map_model(m, users, ctx, &rack) else {
            return Ok(()); // over-capacity contexts may legally fail
        };
        // every card within memory; stage times positive; max_users >= users
        for c in &map.cards {
            prop_assert!(c.memory.total() <= chip.core_mem_bytes,
                         "{} card {} over mem", m.name, c.id);
        }
        prop_assert!(map.max_users(&chip, ctx) >= users,
                     "{} claims {} users but max is {}",
                     m.name, users, map.max_users(&chip, ctx));
        for t in map.stage_times(&chip, PassKind::Decode { micro_batch: 1, ctx }) {
            prop_assert!(t > 0.0 && t < 1.0, "stage time {t}");
        }
        Ok(())
    });
}

#[test]
fn gpipe_bubble_claim_shape() {
    // §III-C: M = S suffices on NorthPole (ring decode has no fill/drain in
    // steady state), whereas GPipe needed M ≈ 4S for <20% bubbles.
    for s in [16usize, 81, 104] {
        assert!(bubble_fraction(s, 4 * s) < 0.21);
        assert!(bubble_fraction(s, s) < 0.51);
        assert!(bubble_fraction(s, 1) > 0.9);
    }
}

#[test]
fn context_scaling_crossover() {
    // Table II shape: halving users while doubling context keeps ITL flat
    // and roughly halves OTPS.
    let rack = RackSpec::northpole_42u();
    let m = find_model("granite-3.3-8b").unwrap();
    let m2k = map_model(&m, 28, 2048, &rack).unwrap();
    let m4k = map_model(&m, 14, 4096, &rack).unwrap();
    let r2k = simulate(&m2k, &rack, SimConfig {
        users: 28, prompt_len: 64, gen_len: 48, requests: 28, chunk: 64 });
    let r4k = simulate(&m4k, &rack, SimConfig {
        users: 14, prompt_len: 64, gen_len: 48, requests: 14, chunk: 64 });
    let b2k = BatchMetrics::from_records(&r2k.seqs);
    let b4k = BatchMetrics::from_records(&r4k.seqs);
    let itl_ratio = b4k.itl.mean() / b2k.itl.mean();
    assert!((0.7..1.3).contains(&itl_ratio), "ITL not flat: {itl_ratio}");
    let otps_ratio = b2k.otps / b4k.otps;
    assert!((1.5..2.6).contains(&otps_ratio), "OTPS ratio {otps_ratio}");
}
