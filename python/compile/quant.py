"""Quantization helpers shared by the kernels, the model, and SiLQ.

The paper's precision scheme (§III-B) labels each layer A{a}-C{c}-W{w}:
activations at a bits, KV cache at c bits, weights at w bits. NorthPole
supports 8/4/2-bit integers; this module provides the quantize/dequantize
primitives for those precisions.

Conventions
-----------
* Weights (W4): symmetric per-output-channel int4 stored as int8 values in
  [-7, 7] plus a float32 scale per output channel. `pack_int4`/`unpack_int4`
  store two nibbles per byte to honour the 4-bit memory footprint.
* Activations (A8): symmetric dynamic per-row int8 — round(x/s) with
  s = max|x|/127 per row. (The paper trains static scales with SiLQ; the
  dynamic stand-in is numerically close and keeps the AOT artifacts
  calibration-free. silq.py implements the trained-scale variant.)
* KV cache (C8/C4): symmetric static per-layer scale, baked into the stage
  artifact as a constant, mirroring the calibrated on-chip cache format.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# Integer ranges for symmetric signed quantization at each precision.
QRANGE = {8: 127, 4: 7, 2: 1}


def quant_dynamic(x, bits: int = 8):
    """Symmetric per-row dynamic quantization.

    x: float array [..., D]. Returns (q int8[..., D], scale f32[..., 1])
    with x ≈ q * scale.
    """
    qmax = QRANGE[bits]
    s = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / qmax
    s = jnp.maximum(s, 1e-8)
    q = jnp.clip(jnp.round(x / s), -qmax, qmax).astype(jnp.int8)
    return q, s.astype(jnp.float32)


def quant_static(x, scale, bits: int = 8):
    """Symmetric quantization with a fixed scale (KV-cache style)."""
    qmax = QRANGE[bits]
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int8)
    return q


def dequant(q, scale):
    return q.astype(jnp.float32) * scale


def quant_weight_np(w: np.ndarray, bits: int = 4):
    """Per-output-channel symmetric weight quantization (numpy, offline).

    w: float [K, N]. Returns (q int8 [K, N] in [-qmax, qmax], scale f32 [N]).
    """
    qmax = QRANGE[bits]
    s = np.abs(w).max(axis=0) / qmax
    s = np.maximum(s, 1e-8).astype(np.float32)
    q = np.clip(np.round(w / s), -qmax, qmax).astype(np.int8)
    return q, s


def fake_quant_weight_np(w: np.ndarray, bits: int = 4) -> np.ndarray:
    q, s = quant_weight_np(w, bits)
    return (q.astype(np.float32) * s).astype(w.dtype)


def pack_int4(q: np.ndarray) -> np.ndarray:
    """Pack int4 values (int8 array in [-8, 7], even first axis) two per byte."""
    assert q.shape[0] % 2 == 0, "pack_int4 needs an even leading dim"
    lo = (q[0::2] & 0xF).astype(np.uint8)
    hi = (q[1::2] & 0xF).astype(np.uint8)
    return (lo | (hi << 4)).astype(np.uint8)


def unpack_int4(p: np.ndarray) -> np.ndarray:
    """Inverse of pack_int4: uint8 [K//2, ...] -> int8 [K, ...] in [-8, 7]."""
    lo = (p & 0xF).astype(np.int8)
    hi = ((p >> 4) & 0xF).astype(np.int8)
    lo = np.where(lo >= 8, lo - 16, lo)
    hi = np.where(hi >= 8, hi - 16, hi)
    out = np.empty((p.shape[0] * 2,) + p.shape[1:], dtype=np.int8)
    out[0::2] = lo
    out[1::2] = hi
    return out


def unpack_int4_jnp(p):
    """jnp version of unpack_int4 for use inside lowered stages."""
    lo = (p & 0xF).astype(jnp.int8)
    hi = ((p >> 4) & 0xF).astype(jnp.int8)
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    k2 = p.shape[0]
    out = jnp.stack([lo, hi], axis=1)  # [K//2, 2, ...]
    return out.reshape((k2 * 2,) + p.shape[1:])
