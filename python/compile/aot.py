"""AOT export: lower every model stage to HLO text + manifest for rust.

Python runs ONCE, here; the rust coordinator is self-contained afterwards.

Interchange is HLO **text** (not a serialized HloModuleProto): jax >= 0.5
emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. Lowered with return_tuple=True, so
the rust side unwraps a tuple for every stage (Literal::to_tuple*).

Usage:
  python -m compile.aot --model granite-test --out ../artifacts
  python -m compile.aot --model granite-tiny --out ../artifacts \
      --ckpt ../artifacts/silq/granite-tiny.quant.npz
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the stage weights ARE the artifact (the
    # card's on-chip contents); the default elides them as `{...}` and the
    # text parser would fill garbage.
    return comp.as_hlo_text(True)


def _sig(avals) -> List[Dict]:
    out = []
    for a in avals:
        out.append({"shape": list(a.shape), "dtype": str(a.dtype)})
    return out


def build_stages(cfg: M.ModelConfig, qp) -> Dict[str, Tuple]:
    """Stage name -> (callable, example_arg_specs)."""
    B, T, D = cfg.batch_slots, cfg.prefill_chunk, cfg.d_model
    L, Hkv, Dh = cfg.max_context, cfg.n_kv_heads, cfg.d_head
    f32, i32, s8 = jnp.float32, jnp.int32, jnp.int8

    def spec(shape, dt):
        return jax.ShapeDtypeStruct(shape, dt)

    cache = spec((B, Hkv, L, Dh), s8)
    stages: Dict[str, Tuple] = {}

    stages["embed_prefill"] = (
        lambda tokens: (M.embed_prefill_stage(qp, cfg, tokens),),
        [spec((1, T), i32)],
    )
    stages["embed_decode"] = (
        lambda tokens: (M.embed_decode_stage(qp, cfg, tokens),),
        [spec((B,), i32)],
    )
    for i in range(cfg.n_layers):
        stages[f"attn_prefill_{i}"] = (
            (lambda i: lambda h, kc, vc, slot, off: M.attn_prefill_stage(
                qp, cfg, i, h, kc, vc, slot, off))(i),
            [spec((1, T, D), f32), cache, cache, spec((), i32), spec((), i32)],
        )
        stages[f"attn_decode_{i}"] = (
            (lambda i: lambda h, kc, vc, pos: M.attn_decode_stage(
                qp, cfg, i, h, kc, vc, pos))(i),
            [spec((B, D), f32), cache, cache, spec((B,), i32)],
        )
        stages[f"mlp_prefill_{i}"] = (
            (lambda i: lambda h: (M.mlp_stage(qp, cfg, i, h),))(i),
            [spec((1, T, D), f32)],
        )
        stages[f"mlp_decode_{i}"] = (
            (lambda i: lambda h: (M.mlp_stage(qp, cfg, i, h),))(i),
            [spec((B, D), f32)],
        )
    for j in range(cfg.lmhead_shards):
        stages[f"lmhead_{j}"] = (
            (lambda j: lambda h: (M.lmhead_stage(qp, cfg, j, h),))(j),
            [spec((B, D), f32)],
        )
        stages[f"lmhead1_{j}"] = (
            (lambda j: lambda h: (M.lmhead_stage(qp, cfg, j, h),))(j),
            [spec((1, D), f32)],
        )
    return stages


def export(cfg: M.ModelConfig, params: Dict[str, np.ndarray], outdir: str) -> dict:
    qp = M.quantize_params(params, cfg)
    os.makedirs(outdir, exist_ok=True)
    stages = build_stages(cfg, qp)
    manifest = {
        "model": cfg.name,
        "config": {
            "vocab": cfg.vocab, "d_model": cfg.d_model,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
            "n_kv_heads": cfg.n_kv_heads, "d_head": cfg.d_head,
            "d_ff": cfg.d_ff, "batch_slots": cfg.batch_slots,
            "prefill_chunk": cfg.prefill_chunk, "max_context": cfg.max_context,
            "lmhead_shards": cfg.lmhead_shards, "shard_vocab": cfg.shard_vocab,
            "a_bits": cfg.a_bits, "c_bits": cfg.c_bits, "w_bits": cfg.w_bits,
            "k_scale": cfg.k_scale, "v_scale": cfg.v_scale,
            "rope_theta": cfg.rope_theta, "eps": cfg.eps,
            "param_count": cfg.param_count(),
        },
        "format": "hlo-text/return-tuple",
        "stages": {},
    }
    for name, (fn, specs) in stages.items():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(outdir, fname), "w") as f:
            f.write(text)
        out_avals = jax.eval_shape(fn, *specs)
        manifest["stages"][name] = {
            "file": fname,
            "inputs": _sig(specs),
            "outputs": _sig(jax.tree_util.tree_leaves(out_avals)),
        }
    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    return manifest


def load_params(cfg: M.ModelConfig, ckpt: str | None, seed: int):
    if ckpt and os.path.exists(ckpt):
        data = np.load(ckpt)
        params = {k: data[k] for k in data.files}
        print(f"loaded checkpoint {ckpt} ({len(params)} tensors)")
        return params
    if ckpt:
        print(f"WARNING: checkpoint {ckpt} not found; using random init")
    return M.init_params(cfg, seed)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--model", default="granite-test", choices=sorted(M.CONFIGS))
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--ckpt", default=None, help=".npz parameter checkpoint")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = M.CONFIGS[args.model]
    params = load_params(cfg, args.ckpt, args.seed)
    outdir = os.path.join(args.out, cfg.name)
    manifest = export(cfg, params, outdir)
    n = len(manifest["stages"])
    total = sum(
        os.path.getsize(os.path.join(outdir, s["file"]))
        for s in manifest["stages"].values()
    )
    print(f"exported {n} stages for {cfg.name} "
          f"({cfg.param_count()/1e6:.2f}M params, {total/1e6:.1f} MB HLO text) "
          f"-> {outdir}")


if __name__ == "__main__":
    main()
