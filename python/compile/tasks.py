"""Synthetic benchmark suite standing in for the paper's 19 evaluation tasks.

Fig 5 of the paper evaluates Granite-3.3-8b on 19 benchmarks (common-sense
reasoning + Open LLM Leaderboard v1/v2). Those need the real 8B model and
the real datasets, neither of which fits this environment (DESIGN.md §4), so
we substitute 19 *procedural* character-level tasks with exact-match
answers. What the substitution preserves: a per-benchmark accuracy
comparison between the bf16 teacher, naive post-training quantization (PTQ),
and SiLQ QAT — the paper's claim being that the QAT model matches bf16 on
average while plain quantization loses accuracy.

Every task emits strings of the form ``<prompt>=<answer>;`` and is scored by
teacher-forced exact match over the answer region.
"""

from __future__ import annotations

import numpy as np

PAD = 0  # token 0 (NUL byte) doubles as padding; never appears in tasks


def _s(r: np.random.Generator, alpha: str, n: int) -> str:
    return "".join(alpha[i] for i in r.integers(0, len(alpha), n))

LOWER = "abcdefgh"
DIGITS = "0123456789"


def t_copy2(r):    x = _s(r, LOWER, 2); return f"C{x}", x
def t_copy3(r):    x = _s(r, LOWER, 3); return f"C{x}", x
def t_copy4(r):    x = _s(r, LOWER, 4); return f"C{x}", x
def t_rev2(r):     x = _s(r, LOWER, 2); return f"R{x}", x[::-1]
def t_rev3(r):     x = _s(r, LOWER, 3); return f"R{x}", x[::-1]
def t_add1(r):
    a, b = int(r.integers(0, 5)), int(r.integers(0, 5))
    return f"{a}+{b}", str(a + b)
def t_add_carry(r):
    a, b = int(r.integers(5, 10)), int(r.integers(5, 10))
    return f"{a}+{b}", f"{a+b:02d}"
def t_sub(r):
    a = int(r.integers(1, 10)); b = int(r.integers(0, a + 1))
    return f"{a}-{b}", str(a - b)
def t_max(r):
    a, b = r.integers(0, 10, 2)
    return f"M{a}{b}", str(max(a, b))
def t_min(r):
    a, b = r.integers(0, 10, 2)
    return f"m{a}{b}", str(min(a, b))
def t_succ(r):
    a = int(r.integers(0, 9)); return f"S{a}", str(a + 1)
def t_pred(r):
    a = int(r.integers(1, 10)); return f"P{a}", str(a - 1)
def t_count(r):
    c = LOWER[r.integers(0, len(LOWER))]
    n = int(r.integers(1, 5))
    return f"N{c * n}", str(n)
def t_parity(r):
    n = int(r.integers(1, 7))
    bits = _s(r, "01", n)
    return f"p{bits}", str(bits.count("1") % 2)
def t_last(r):
    x = _s(r, LOWER, int(r.integers(2, 5))); return f"L{x}", x[-1]
def t_first(r):
    x = _s(r, LOWER, int(r.integers(2, 5))); return f"F{x}", x[0]
def t_dup(r):
    x = _s(r, LOWER, 2); return f"D{x}", x + x
def t_sort2(r):
    a, b = r.integers(0, 10, 2)
    lo, hi = sorted((int(a), int(b)))
    return f"s{a}{b}", f"{lo}{hi}"
def t_alt(r):
    c = LOWER[r.integers(0, len(LOWER))]
    d = LOWER[r.integers(0, len(LOWER))]
    n = int(r.integers(2, 4))
    return f"A{c}{d}{n}", (c + d) * n


# The 19 benchmarks, named after the skill they probe.
BENCHMARKS = {
    "copy-2": t_copy2, "copy-3": t_copy3, "copy-4": t_copy4,
    "reverse-2": t_rev2, "reverse-3": t_rev3,
    "add": t_add1, "add-carry": t_add_carry, "sub": t_sub,
    "max": t_max, "min": t_min, "succ": t_succ, "pred": t_pred,
    "count": t_count, "parity": t_parity,
    "last": t_last, "first": t_first,
    "dup": t_dup, "sort-2": t_sort2, "alternate": t_alt,
}
assert len(BENCHMARKS) == 19


def encode(s: str) -> np.ndarray:
    return np.frombuffer(s.encode(), np.uint8).astype(np.int32)


def make_example(r: np.random.Generator, task=None):
    """Returns (tokens i32[seq], answer_mask bool[seq]) for one task item."""
    if task is None:
        task = list(BENCHMARKS.values())[r.integers(0, len(BENCHMARKS))]
    prompt, answer = task(r)
    s = f"{prompt}={answer};"
    toks = encode(s)
    mask = np.zeros(len(toks), bool)
    a0 = len(prompt) + 1
    mask[a0:a0 + len(answer)] = True
    return toks, mask


def make_batch(r: np.random.Generator, batch: int, seqlen: int, task=None):
    """Pack task items into fixed-length rows. Returns
    (tokens i32[B,S], loss_mask f32[B,S], answer_mask bool[B,S])."""
    toks = np.full((batch, seqlen), PAD, np.int32)
    amask = np.zeros((batch, seqlen), bool)
    lmask = np.zeros((batch, seqlen), np.float32)
    for b in range(batch):
        pos = 0
        while pos < seqlen - 4:
            t, m = make_example(r, task)
            n = min(len(t), seqlen - pos)
            toks[b, pos:pos + n] = t[:n]
            amask[b, pos:pos + n] = m[:n]
            lmask[b, pos:pos + n] = 1.0
            pos += n
    return toks, lmask, amask


def eval_accuracy(forward, tokens, amask) -> float:
    """Teacher-forced exact match over answer positions.

    forward: tokens i32[B,S] -> logits f32[B,S,V].
    Position i is predicted from logits at i-1.
    """
    logits = np.asarray(forward(tokens))
    pred = logits[:, :-1].argmax(-1)          # prediction for position i+1
    tgt = tokens[:, 1:]
    m = amask[:, 1:]
    correct = (pred == tgt) | ~m
    # an example row counts as correct only if all its answer tokens match
    per_row = np.logical_and.reduce(correct, axis=1)
    has_answer = m.any(axis=1)
    if not has_answer.any():
        return float("nan")
    return float(per_row[has_answer].mean())


def benchmark_suite(forward, seed: int = 1234, n_examples: int = 64,
                    seqlen: int = 16):
    """Score `forward` on all 19 benchmarks. One task item per row so the
    exact-match criterion is per-example."""
    scores = {}
    for name, task in BENCHMARKS.items():
        r = np.random.default_rng(seed + hash(name) % 2**16)
        toks = np.full((n_examples, seqlen), PAD, np.int32)
        amask = np.zeros((n_examples, seqlen), bool)
        for b in range(n_examples):
            t, m = make_example(r, task)
            n = min(len(t), seqlen)
            toks[b, :n] = t[:n]
            amask[b, :n] = m[:n]
        scores[name] = 100.0 * eval_accuracy(forward, toks, amask)
    return scores
