"""SiLQ: Simple LLM Quantization-aware training (Esser et al. 2025) — §VI.A.

The paper fine-tunes the bf16 Granite-3.3-8b to A8-C8-W4 with SiLQ
(learned-step-size quantizers + knowledge distillation from the
full-precision model, short fine-tune on a tiny fraction of training data)
and shows the quantized model matches bf16 accuracy across 19 benchmarks
(Fig 5, averages 56.8 quantized vs 56.4 bf16).

This module reproduces the algorithm end-to-end at laptop scale:

1. pretrain a bf16(f32) teacher on the synthetic corpus (tasks.py),
2. quantize W4 / A8 / C8 with LSQ learned step sizes (straight-through
   estimator), distill teacher -> student for a short fine-tune,
3. evaluate teacher / PTQ (no fine-tune) / SiLQ on the 19 benchmarks and
   write artifacts/silq/results.json (rendered by `cargo bench --bench
   fig5_accuracy` and EXPERIMENTS.md),
4. save the QAT weights as an .npz checkpoint so `make artifacts` bakes the
   *fine-tuned* quantized weights into the served HLO stages.

Optimizer is a hand-rolled Adam (no optax in this environment).
"""

from __future__ import annotations

import argparse
import json
import os
import time
from functools import partial
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from . import quant
from . import tasks


# ---------------------------------------------------------------- quantizers

def _round_ste(x):
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


def _grad_scale(s, g):
    return s * g + jax.lax.stop_gradient(s * (1.0 - g))


def lsq_weight(w, s, bits: int):
    """LSQ per-output-channel weight fake-quant. w [K,N], s [N]."""
    qp = quant.QRANGE[bits]
    g = 1.0 / jnp.sqrt(w.shape[0] * qp)
    s = _grad_scale(jnp.maximum(s, 1e-8), g)
    v = jnp.clip(w / s[None, :], -qp, qp)
    return _round_ste(v) * s[None, :]


def init_weight_scale(w: np.ndarray, bits: int) -> np.ndarray:
    qp = quant.QRANGE[bits]
    return (2.0 * np.abs(w).mean(axis=0) / np.sqrt(qp)).astype(np.float32) + 1e-6


def act_quant_ste(x, bits: int = 8):
    """Dynamic per-row activation fake-quant with STE — the same quantizer
    the inference path applies (quant.quant_dynamic), made differentiable."""
    qp = quant.QRANGE[bits]
    s = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True) / qp, 1e-8)
    s = jax.lax.stop_gradient(s)
    return _round_ste(jnp.clip(x / s, -qp, qp)) * s


def cache_quant_ste(x, scale: float, bits: int = 8):
    qp = quant.QRANGE[bits]
    return _round_ste(jnp.clip(x / scale, -qp, qp)) * scale


# ---------------------------------------------------------------- student fwd

def forward_student(params, wscales, cfg: M.ModelConfig, tokens):
    """Differentiable quantized forward: W4 LSQ weights, A8 STE activations,
    C8 STE KV cache — the QAT mirror of model.forward_ref."""
    from .kernels import ref

    B, T = tokens.shape
    d, hh, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    group = hh // hkv

    def qw(name):
        return lsq_weight(params[name], wscales[name], cfg.w_bits)

    h = jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.arange(T, dtype=jnp.int32)

    for i in range(cfg.n_layers):
        pre = f"l{i}."
        x = ref.rmsnorm_ref(h.reshape(B * T, d), params[pre + "g1"], cfg.eps)
        x = act_quant_ste(x, cfg.a_bits)
        q = (x @ qw(pre + "wq")).reshape(B, T, hh, dh)
        k = (x @ qw(pre + "wk")).reshape(B, T, hkv, dh)
        v = (x @ qw(pre + "wv")).reshape(B, T, hkv, dh)
        q = M.rope(q, positions[None, :], cfg.rope_theta)
        k = M.rope(k, positions[None, :], cfg.rope_theta)
        k = cache_quant_ste(k, cfg.k_scale, cfg.c_bits)
        v = cache_quant_ste(v, cfg.v_scale, cfg.c_bits)
        k = jnp.repeat(k, group, axis=2)
        v = jnp.repeat(v, group, axis=2)
        scores = jnp.einsum("bthd,bshd->bhts", q, k) / jnp.sqrt(jnp.float32(dh))
        mask = jnp.tril(jnp.ones((T, T), bool))
        scores = jnp.where(mask[None, None], scores, -1e30)
        p = jax.nn.softmax(scores, axis=-1)
        attn = jnp.einsum("bhts,bshd->bthd", p, v).reshape(B * T, hh * dh)
        attn = act_quant_ste(attn, cfg.a_bits)
        h = h + (attn @ qw(pre + "wo")).reshape(B, T, d)

        x = ref.rmsnorm_ref(h.reshape(B * T, d), params[pre + "g2"], cfg.eps)
        x = act_quant_ste(x, cfg.a_bits)
        g = x @ qw(pre + "wg")
        u = x @ qw(pre + "wu")
        y = ref.swiglu_ref(g, u)
        y = act_quant_ste(y, cfg.a_bits)
        h = h + (y @ qw(pre + "wd")).reshape(B, T, d)

    x = ref.rmsnorm_ref(h.reshape(B * T, d), params["final_g"], cfg.eps)
    x = act_quant_ste(x, cfg.a_bits)
    return (x @ qw("lmhead")).reshape(B, T, cfg.vocab)


QUANT_KEYS = (".wq", ".wk", ".wv", ".wo", ".wg", ".wu", ".wd")


def is_quantized(name: str) -> bool:
    return name.endswith(QUANT_KEYS) or name == "lmhead"


def fold_lsq_into_params(params, wscales, cfg) -> Dict[str, np.ndarray]:
    """Bake the learned quantizers into plain float weights (which
    quantize_params then re-quantizes losslessly, because they already sit
    exactly on the LSQ grid... up to the per-channel max re-derivation)."""
    out = {}
    for k, v in params.items():
        if is_quantized(k):
            out[k] = np.asarray(lsq_weight(jnp.asarray(v), jnp.asarray(wscales[k]),
                                           cfg.w_bits), dtype=np.float32)
        else:
            out[k] = np.asarray(v, dtype=np.float32)
    return out


# ---------------------------------------------------------------- optimizer

def adam_init(params):
    z = lambda: {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": z(), "v": z(), "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = {k: b1 * state["m"][k] + (1 - b1) * grads[k] for k in grads}
    v = {k: b2 * state["v"][k] + (1 - b2) * jnp.square(grads[k]) for k in grads}
    mh = {k: m[k] / (1 - b1 ** t) for k in m}
    vh = {k: v[k] / (1 - b2 ** t) for k in v}
    new = {k: params[k] - lr * mh[k] / (jnp.sqrt(vh[k]) + eps) for k in params}
    return new, {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------- training

def ce_loss(logits, tokens, lmask):
    tgt = tokens[:, 1:]
    lg = logits[:, :-1]
    m = lmask[:, 1:]
    logp = jax.nn.log_softmax(lg, axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)


def kd_loss(student_logits, teacher_logits, lmask, tau: float = 2.0):
    tl = jax.nn.log_softmax(teacher_logits / tau, axis=-1)
    sl = jax.nn.log_softmax(student_logits / tau, axis=-1)
    kl = jnp.sum(jnp.exp(tl) * (tl - sl), axis=-1)
    m = lmask
    return jnp.sum(kl * m) / jnp.maximum(jnp.sum(m), 1.0) * tau * tau


def pretrain_teacher(cfg, steps, batch, seqlen, lr, seed, log_every=100):
    params = {k: jnp.asarray(v) for k, v in M.init_params(cfg, seed).items()}
    opt = adam_init(params)
    r = np.random.default_rng(seed + 1)

    @jax.jit
    def step(params, opt, toks, lmask, lr):
        def loss_fn(p):
            return ce_loss(M.forward_float(p, cfg, toks), toks, lmask)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt = adam_update(params, grads, opt, lr)
        return params, opt, loss

    t0 = time.time()
    for i in range(steps):
        toks, lmask, _ = tasks.make_batch(r, batch, seqlen)
        cur_lr = lr * min(1.0, (i + 1) / 50) * (0.1 ** (i / max(steps, 1)))
        params, opt, loss = step(params, opt, jnp.asarray(toks),
                                 jnp.asarray(lmask), cur_lr)
        if i % log_every == 0 or i == steps - 1:
            print(f"  teacher step {i:5d} loss {float(loss):.4f} "
                  f"({time.time()-t0:.0f}s)")
    return {k: np.asarray(v) for k, v in params.items()}


def silq_finetune(cfg, teacher, steps, batch, seqlen, lr, seed, log_every=50):
    """LSQ + distillation fine-tune, per the SiLQ recipe."""
    params = {k: jnp.asarray(v) for k, v in teacher.items()}
    tparams = {k: jnp.asarray(v) for k, v in teacher.items()}
    wscales = {k: jnp.asarray(init_weight_scale(np.asarray(v), cfg.w_bits))
               for k, v in teacher.items() if is_quantized(k)}
    opt_p = adam_init(params)
    opt_s = adam_init(wscales)
    r = np.random.default_rng(seed + 2)

    @jax.jit
    def step(params, wscales, opt_p, opt_s, toks, lmask, lr):
        tlogits = M.forward_float(tparams, cfg, toks)

        def loss_fn(p, s):
            slogits = forward_student(p, s, cfg, toks)
            return (kd_loss(slogits, tlogits, lmask)
                    + 0.5 * ce_loss(slogits, toks, lmask))

        loss, (gp, gs) = jax.value_and_grad(loss_fn, argnums=(0, 1))(params, wscales)
        params, opt_p = adam_update(params, gp, opt_p, lr)
        wscales, opt_s = adam_update(wscales, gs, opt_s, lr * 0.1)
        return params, wscales, opt_p, opt_s, loss

    t0 = time.time()
    for i in range(steps):
        toks, lmask, _ = tasks.make_batch(r, batch, seqlen)
        cur_lr = lr * min(1.0, (i + 1) / 20) * (0.1 ** (i / max(steps, 1)))
        params, wscales, opt_p, opt_s, loss = step(
            params, wscales, opt_p, opt_s,
            jnp.asarray(toks), jnp.asarray(lmask), cur_lr)
        if i % log_every == 0 or i == steps - 1:
            print(f"  silq step {i:5d} loss {float(loss):.4f} "
                  f"({time.time()-t0:.0f}s)")
    return ({k: np.asarray(v) for k, v in params.items()},
            {k: np.asarray(v) for k, v in wscales.items()})


# ---------------------------------------------------------------- evaluation

def eval_models(cfg, teacher, ptq_params, silq_params, n_examples=64):
    """Score teacher (float), PTQ, and SiLQ on the 19 benchmarks.

    PTQ/SiLQ are evaluated through the *inference* quantized path
    (model.forward_ref with quantize_params) — i.e. exactly what the AOT
    artifacts compute — not through the training-time STE path.
    """
    tj = {k: jnp.asarray(v) for k, v in teacher.items()}

    @jax.jit
    def f_teacher(toks):
        return M.forward_float(tj, cfg, toks)

    def quant_forward(params):
        qp = M.quantize_params(params, cfg)
        qpj = {k: (jnp.asarray(v[0]), jnp.asarray(v[1])) if isinstance(v, tuple)
               else jnp.asarray(v) for k, v in qp.items()}

        @jax.jit
        def f(toks):
            return M.forward_ref(qpj, cfg, toks)
        return f

    out = {}
    out["bf16"] = tasks.benchmark_suite(lambda t: f_teacher(jnp.asarray(t)),
                                        n_examples=n_examples)
    fp = quant_forward(ptq_params)
    out["ptq-w4a8"] = tasks.benchmark_suite(lambda t: fp(jnp.asarray(t)),
                                            n_examples=n_examples)
    fs = quant_forward(silq_params)
    out["silq-w4a8"] = tasks.benchmark_suite(lambda t: fs(jnp.asarray(t)),
                                             n_examples=n_examples)
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--model", default="granite-tiny", choices=sorted(M.CONFIGS))
    ap.add_argument("--out", default="../artifacts/silq")
    ap.add_argument("--pretrain-steps", type=int, default=900)
    ap.add_argument("--qat-steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seqlen", type=int, default=48)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = M.CONFIGS[args.model]
    os.makedirs(args.out, exist_ok=True)
    print(f"[silq] pretraining bf16 teacher ({cfg.param_count()/1e6:.2f}M params)")
    teacher = pretrain_teacher(cfg, args.pretrain_steps, args.batch,
                               args.seqlen, args.lr, args.seed)
    np.savez(os.path.join(args.out, f"{cfg.name}.teacher.npz"), **teacher)

    print("[silq] LSQ + distillation fine-tune (A%d-C%d-W%d)"
          % (cfg.a_bits, cfg.c_bits, cfg.w_bits))
    sparams, wscales = silq_finetune(cfg, teacher, args.qat_steps, args.batch,
                                     args.seqlen, args.lr * 0.3, args.seed)
    folded = fold_lsq_into_params(sparams, wscales, cfg)
    np.savez(os.path.join(args.out, f"{cfg.name}.quant.npz"), **folded)

    print("[silq] evaluating on the 19-benchmark suite")
    scores = eval_models(cfg, teacher, teacher, folded)
    avg = {k: float(np.mean(list(v.values()))) for k, v in scores.items()}
    results = {
        "model": cfg.name,
        "precision": f"A{cfg.a_bits}-C{cfg.c_bits}-W{cfg.w_bits}",
        "pretrain_steps": args.pretrain_steps,
        "qat_steps": args.qat_steps,
        "benchmarks": scores,
        "averages": avg,
        "paper": {"bf16_avg": 56.4, "quant_avg": 56.8,
                  "note": "Granite-3.3-8b on 19 real benchmarks (Fig 5)"},
    }
    with open(os.path.join(args.out, "results.json"), "w") as f:
        json.dump(results, f, indent=1, sort_keys=True)
    print(json.dumps(avg, indent=1))
    print(f"[silq] wrote {args.out}/results.json")


if __name__ == "__main__":
    main()
