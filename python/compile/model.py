"""Layer-2: Granite-3.3-style decoder-only transformer, staged for NorthPole.

The model is expressed as *stage functions* that mirror the paper's card
mapping (§III-A, Fig 2): the attention block and the MLP block of every
transformer layer are separate stages (separate NorthPole cards for the 8B
model), the embedding is its own stage, and the output layer is split into
tensor-parallel shards. Each stage closes over its quantized weights so that
`aot.py` lowers them into the stage's HLO artifact as constants — the
compile-time analog of "weights reside entirely in on-chip memory".

Precision follows §III-B A8-C8-W4: int4 per-channel weights, dynamic int8
activations, static-scale int8 KV cache.

Stage I/O contract (shared with rust/src/runtime — see manifest.json):

  embed_prefill : tokens i32[1,T]                                -> h f32[1,T,D]
  embed_decode  : tokens i32[B]                                  -> h f32[B,D]
  attn_prefill_i: (h f32[1,T,D], kc s8[B,Hkv,L,Dh], vc s8[...],
                   slot i32[], pos_off i32[])                    -> (h', kc', vc')
  attn_decode_i : (h f32[B,D], kc, vc, positions i32[B])         -> (h', kc', vc')
  mlp_prefill_i : h f32[1,T,D]                                   -> h'
  mlp_decode_i  : h f32[B,D]                                     -> h'
  lmhead_j      : h f32[B,D]                                     -> logits f32[B,V/S]
  lmhead1_j     : h f32[1,D]                                     -> logits f32[1,V/S]

Prefill runs one sequence at a time (B=1 chunks of T tokens) writing into
that sequence's cache slot; decode runs the whole mini-batch of B slots —
exactly the sequence-worker / slot model of §IV.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from . import quant
from .kernels import (
    decode_attention,
    prefill_attention,
    quant_matmul,
    rmsnorm_quant,
    swiglu,
)


# --------------------------------------------------------------------------
# Configurations
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    """Architecture + precision + serving-shape configuration."""

    name: str = "granite-tiny"
    vocab: int = 384
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    n_kv_heads: int = 2
    d_ff: int = 384
    rope_theta: float = 10000.0
    eps: float = 1e-6
    # precision (A{a}-C{c}-W{w}) — §III-B
    a_bits: int = 8
    c_bits: int = 8
    w_bits: int = 4
    # static KV-cache scales (C8), calibrated constants baked into artifacts
    k_scale: float = 0.05
    v_scale: float = 0.05
    # serving shapes
    batch_slots: int = 8        # decode mini-batch slots (N in §III-C)
    prefill_chunk: int = 32     # T: prefill chunk length
    max_context: int = 256      # L: on-chip KV capacity per sequence
    lmhead_shards: int = 4      # output-layer tensor parallelism (Fig 2)

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    @property
    def shard_vocab(self) -> int:
        assert self.vocab % self.lmhead_shards == 0
        return self.vocab // self.lmhead_shards

    def param_count(self) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab
        dh, h, hkv = self.d_head, self.n_heads, self.n_kv_heads
        per_layer = d * (h * dh) + 2 * d * (hkv * dh) + (h * dh) * d + 3 * d * f + 2 * d
        return v * d + self.n_layers * per_layer + d + d * v


# Named configurations. Full-size configs are used by the rust mapper/simulator
# (shapes only); the tiny/small ones are actually lowered and executed.
CONFIGS: Dict[str, ModelConfig] = {
    # test-scale: fast pytest sweeps
    "granite-test": ModelConfig(
        name="granite-test", vocab=64, d_model=32, n_layers=2, n_heads=2,
        n_kv_heads=1, d_ff=64, batch_slots=4, prefill_chunk=8, max_context=32,
        lmhead_shards=4,
    ),
    # demo-scale: the end-to-end serving example (a few M params)
    "granite-tiny": ModelConfig(name="granite-tiny"),
    # a bigger CPU-runnable config for throughput experiments
    "granite-small": ModelConfig(
        name="granite-small", vocab=384, d_model=256, n_layers=6, n_heads=8,
        n_kv_heads=4, d_ff=768, batch_slots=8, prefill_chunk=64,
        max_context=512,
    ),
}


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------


def init_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, np.ndarray]:
    """Float32 parameters, truncated-normal-ish init (numpy, offline)."""
    r = np.random.default_rng(seed)
    d, f = cfg.d_model, cfg.d_ff
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head

    def w(shape, scale):
        return (r.standard_normal(shape) * scale).astype(np.float32)

    p: Dict[str, np.ndarray] = {
        "embed": w((cfg.vocab, d), 0.02),
        "final_g": np.ones(d, np.float32),
        "lmhead": w((d, cfg.vocab), 0.02),
    }
    for i in range(cfg.n_layers):
        s_in = 1.0 / np.sqrt(d)
        s_ff = 1.0 / np.sqrt(f)
        p[f"l{i}.g1"] = np.ones(d, np.float32)
        p[f"l{i}.wq"] = w((d, h * dh), s_in)
        p[f"l{i}.wk"] = w((d, hkv * dh), s_in)
        p[f"l{i}.wv"] = w((d, hkv * dh), s_in)
        p[f"l{i}.wo"] = w((h * dh, d), s_in)
        p[f"l{i}.g2"] = np.ones(d, np.float32)
        p[f"l{i}.wg"] = w((d, f), s_in)
        p[f"l{i}.wu"] = w((d, f), s_in)
        p[f"l{i}.wd"] = w((f, d), s_ff)
    return p


def quantize_params(params: Dict[str, np.ndarray], cfg: ModelConfig):
    """Quantize every projection weight to W4 (per-output-channel int4).

    Returns {name: (q int8, s f32[N])} for matmul weights plus the float
    tensors (embed, norms) passed through.
    """
    out = {}
    for k, v in params.items():
        if k.endswith((".wq", ".wk", ".wv", ".wo", ".wg", ".wu", ".wd")) or k == "lmhead":
            out[k] = quant.quant_weight_np(v, cfg.w_bits)
        else:
            out[k] = v
    return out


# --------------------------------------------------------------------------
# Building blocks
# --------------------------------------------------------------------------


def rope(x, positions, theta: float):
    """Rotary position embedding.

    x: f32 [..., H, Dh]; positions: i32 broadcastable to x.shape[:-2].
    """
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # [..., half]
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _qmm(x, p, name):
    """rmsnorm-less quantized matmul: dynamically quantize x, W4 matmul."""
    xq, xs = quant.quant_dynamic(x, 8)
    wq, ws = p[name]
    return quant_matmul(xq, xs, wq, ws)


def _norm_qmm(x, g, p, name):
    """Fused rmsnorm+quant (Pallas) then W4 matmul (Pallas)."""
    xq, xs = rmsnorm_quant(x, g)
    wq, ws = p[name]
    return quant_matmul(xq, xs, wq, ws)


# --------------------------------------------------------------------------
# Stage functions (quantized; lowered by aot.py)
# --------------------------------------------------------------------------


def embed_prefill_stage(qp, cfg: ModelConfig, tokens):
    """tokens i32[1,T] -> h f32[1,T,D]."""
    return jnp.take(qp["embed"], tokens, axis=0)


def embed_decode_stage(qp, cfg: ModelConfig, tokens):
    """tokens i32[B] -> h f32[B,D]."""
    return jnp.take(qp["embed"], tokens, axis=0)


def attn_prefill_stage(qp, cfg: ModelConfig, layer: int, h, k_cache, v_cache, slot, pos_off):
    """One attention block, prefill chunk for a single sequence.

    h: f32[1,T,D]; k_cache/v_cache: int8[B,Hkv,L,Dh]; slot, pos_off: i32[].
    Writes the chunk's K/V into cache[slot, :, pos_off:pos_off+T) and
    attends causally over everything written so far.
    """
    T, d = h.shape[1], cfg.d_model
    hh, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    x = h.reshape(T, d)
    pre = f"l{layer}."

    xq, xs = rmsnorm_quant(x, qp[pre + "g1"])
    q = quant_matmul(xq, xs, *qp[pre + "wq"]).reshape(T, hh, dh)
    k = quant_matmul(xq, xs, *qp[pre + "wk"]).reshape(T, hkv, dh)
    v = quant_matmul(xq, xs, *qp[pre + "wv"]).reshape(T, hkv, dh)

    positions = pos_off + jnp.arange(T, dtype=jnp.int32)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    k8 = quant.quant_static(k, cfg.k_scale, cfg.c_bits)  # [T,Hkv,Dh]
    v8 = quant.quant_static(v, cfg.v_scale, cfg.c_bits)
    # Write chunk into this sequence's cache slot.
    kc_slot = jax.lax.dynamic_slice(
        k_cache, (slot, 0, 0, 0), (1, hkv, cfg.max_context, dh))
    vc_slot = jax.lax.dynamic_slice(
        v_cache, (slot, 0, 0, 0), (1, hkv, cfg.max_context, dh))
    kc_slot = jax.lax.dynamic_update_slice(
        kc_slot, k8.transpose(1, 0, 2)[None], (0, 0, pos_off, 0))
    vc_slot = jax.lax.dynamic_update_slice(
        vc_slot, v8.transpose(1, 0, 2)[None], (0, 0, pos_off, 0))
    k_cache = jax.lax.dynamic_update_slice(k_cache, kc_slot, (slot, 0, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, vc_slot, (slot, 0, 0, 0))

    attn = prefill_attention(
        q[None], kc_slot, vc_slot,
        jnp.full((1,), pos_off, jnp.int32), cfg.k_scale, cfg.v_scale,
    )  # [1,T,H,Dh]
    o = _qmm(attn.reshape(T, hh * dh), qp, pre + "wo")
    return (x + o).reshape(1, T, d), k_cache, v_cache


def attn_decode_stage(qp, cfg: ModelConfig, layer: int, h, k_cache, v_cache, positions):
    """One attention block, one decode step for the whole mini-batch.

    h: f32[B,D]; positions i32[B] = index where this token's K/V is written
    (== number of tokens already in the cache for that slot).
    """
    B, d = h.shape
    hh, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    pre = f"l{layer}."

    xq, xs = rmsnorm_quant(h, qp[pre + "g1"])
    q = quant_matmul(xq, xs, *qp[pre + "wq"]).reshape(B, hh, dh)
    k = quant_matmul(xq, xs, *qp[pre + "wk"]).reshape(B, hkv, dh)
    v = quant_matmul(xq, xs, *qp[pre + "wv"]).reshape(B, hkv, dh)

    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    k8 = quant.quant_static(k, cfg.k_scale, cfg.c_bits)  # [B,Hkv,Dh]
    v8 = quant.quant_static(v, cfg.v_scale, cfg.c_bits)

    def write(cache_b, kv_b, pos):
        # cache_b [Hkv,L,Dh]; kv_b [Hkv,Dh]
        return jax.lax.dynamic_update_slice(cache_b, kv_b[:, None, :], (0, pos, 0))

    k_cache = jax.vmap(write)(k_cache, k8, positions)
    v_cache = jax.vmap(write)(v_cache, v8, positions)

    attn = decode_attention(
        q, k_cache, v_cache, positions + 1, cfg.k_scale, cfg.v_scale)
    o = _qmm(attn.reshape(B, hh * dh), qp, pre + "wo")
    return h + o, k_cache, v_cache


def mlp_stage(qp, cfg: ModelConfig, layer: int, h):
    """One MLP (SwiGLU) block; works on f32[M,D] for any M."""
    shape = h.shape
    x = h.reshape(-1, cfg.d_model)
    pre = f"l{layer}."
    xq, xs = rmsnorm_quant(x, qp[pre + "g2"])
    g = quant_matmul(xq, xs, *qp[pre + "wg"])
    u = quant_matmul(xq, xs, *qp[pre + "wu"])
    y = swiglu(g, u)
    o = _qmm(y, qp, pre + "wd")
    return (x + o).reshape(shape)


def lmhead_stage(qp, cfg: ModelConfig, shard: int, h):
    """Final norm + tensor-parallel vocabulary projection shard.

    h: f32[M,D] -> logits f32[M, vocab/shards] for shard `shard`.
    """
    sv = cfg.shard_vocab
    wq, ws = qp["lmhead"]
    wq = wq[:, shard * sv:(shard + 1) * sv]
    ws = ws[shard * sv:(shard + 1) * sv]
    xq, xs = rmsnorm_quant(h, qp["final_g"])
    return quant_matmul(xq, xs, wq, ws)


# --------------------------------------------------------------------------
# Whole-model reference paths (oracles for tests & the training teacher)
# --------------------------------------------------------------------------


def forward_ref(qp, cfg: ModelConfig, tokens):
    """Quantized full forward over a prompt batch: tokens i32[B,T] -> logits
    f32[B,T,V]. Pure-jnp oracle for the staged/PJRT path (same quantization
    choices, no Pallas, no staging)."""
    from .kernels import ref

    B, T = tokens.shape
    d, hh, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    h = jnp.take(qp["embed"], tokens, axis=0)  # [B,T,D]
    positions = jnp.arange(T, dtype=jnp.int32)

    for i in range(cfg.n_layers):
        pre = f"l{i}."
        x = h.reshape(B * T, d)
        xq, xs = ref.rmsnorm_quant_ref(x, qp[pre + "g1"], cfg.eps)
        q = ref.quant_matmul_ref(xq, xs, *qp[pre + "wq"]).reshape(B, T, hh, dh)
        k = ref.quant_matmul_ref(xq, xs, *qp[pre + "wk"]).reshape(B, T, hkv, dh)
        v = ref.quant_matmul_ref(xq, xs, *qp[pre + "wv"]).reshape(B, T, hkv, dh)
        q = rope(q, positions[None, :], cfg.rope_theta)
        k = rope(k, positions[None, :], cfg.rope_theta)
        k8 = quant.quant_static(k, cfg.k_scale, cfg.c_bits).transpose(0, 2, 1, 3)
        v8 = quant.quant_static(v, cfg.v_scale, cfg.c_bits).transpose(0, 2, 1, 3)
        attn = ref.prefill_attention_ref(q, k8, v8, cfg.k_scale, cfg.v_scale, 0)
        aq, as_ = quant.quant_dynamic(attn.reshape(B * T, hh * dh), 8)
        o = ref.quant_matmul_ref(aq, as_, *qp[pre + "wo"])
        h = h + o.reshape(B, T, d)

        x = h.reshape(B * T, d)
        xq, xs = ref.rmsnorm_quant_ref(x, qp[pre + "g2"], cfg.eps)
        g = ref.quant_matmul_ref(xq, xs, *qp[pre + "wg"])
        u = ref.quant_matmul_ref(xq, xs, *qp[pre + "wu"])
        y = ref.swiglu_ref(g, u)
        yq, ys = quant.quant_dynamic(y, 8)
        o = ref.quant_matmul_ref(yq, ys, *qp[pre + "wd"])
        h = h + o.reshape(B, T, d)

    x = h.reshape(B * T, d)
    xq, xs = ref.rmsnorm_quant_ref(x, qp["final_g"], cfg.eps)
    logits = ref.quant_matmul_ref(xq, xs, *qp["lmhead"])
    return logits.reshape(B, T, cfg.vocab)


def forward_float(params, cfg: ModelConfig, tokens):
    """Unquantized bf16-style forward (the 'teacher'): tokens i32[B,T] ->
    logits f32[B,T,V]. Differentiable; used by silq.py for pretraining and
    as the distillation teacher."""
    from .kernels import ref

    B, T = tokens.shape
    d, hh, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    group = hh // hkv
    h = jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.arange(T, dtype=jnp.int32)

    for i in range(cfg.n_layers):
        pre = f"l{i}."
        x = ref.rmsnorm_ref(h.reshape(B * T, d), params[pre + "g1"], cfg.eps)
        q = (x @ params[pre + "wq"]).reshape(B, T, hh, dh)
        k = (x @ params[pre + "wk"]).reshape(B, T, hkv, dh)
        v = (x @ params[pre + "wv"]).reshape(B, T, hkv, dh)
        q = rope(q, positions[None, :], cfg.rope_theta)
        k = rope(k, positions[None, :], cfg.rope_theta)
        k = jnp.repeat(k, group, axis=2)
        v = jnp.repeat(v, group, axis=2)
        scores = jnp.einsum("bthd,bshd->bhts", q, k) / jnp.sqrt(jnp.float32(dh))
        mask = jnp.tril(jnp.ones((T, T), bool))
        scores = jnp.where(mask[None, None], scores, -1e30)
        p = jax.nn.softmax(scores, axis=-1)
        attn = jnp.einsum("bhts,bshd->bthd", p, v).reshape(B * T, hh * dh)
        h = h + (attn @ params[pre + "wo"]).reshape(B, T, d)

        x = ref.rmsnorm_ref(h.reshape(B * T, d), params[pre + "g2"], cfg.eps)
        g = x @ params[pre + "wg"]
        u = x @ params[pre + "wu"]
        y = ref.swiglu_ref(g, u)
        h = h + (y @ params[pre + "wd"]).reshape(B, T, d)

    x = ref.rmsnorm_ref(h.reshape(B * T, d), params["final_g"], cfg.eps)
    return (x @ params["lmhead"]).reshape(B, T, cfg.vocab)
