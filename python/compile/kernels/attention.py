"""GQA attention over a quantized, resident KV cache — Pallas kernels.

The paper's key enabler (§III-C) is that the entire KV cache lives in
on-chip memory, so decode attention at micro-batch 1 is a single-row matvec
against a resident cache block. These kernels express that:

* grid walks (batch, kv-head): each step sees one sequence's cache block for
  one kv head — the NorthPole core-group holding that head's cache;
* the cache arrives as int8 (C8) and is dequantized at the VMEM edge;
* queries are a single row (decode) or a chunk (prefill), i.e. the kernels
  are tiled on the head/cache dimensions, NOT the batch dimension — this is
  what "efficient at micro-batch size 1" means for the kernel.

Hardware adaptation: a GPU flash-attention kernel would tile KV into
shared-memory pages and iterate; here the BlockSpec hands the whole resident
block to the kernel (NorthPole never pages KV), and softmax runs at f32 in
VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, *, k_scale, v_scale, dh):
    """One (sequence, kv-head) step: q [1, G, Dh] against cache [1, L, Dh]."""
    q = q_ref[0, 0]                                # [G, Dh] f32
    k = k_ref[0, 0].astype(jnp.float32) * k_scale  # [L, Dh]
    v = v_ref[0, 0].astype(jnp.float32) * v_scale  # [L, Dh]
    length = len_ref[0, 0]
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
    scores = scores * (1.0 / jnp.sqrt(jnp.float32(dh)))
    mask = jnp.arange(k.shape[0])[None, :] < length
    scores = jnp.where(mask, scores, -1e30)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[0, 0] = jnp.dot(p, v, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("k_scale", "v_scale"))
def decode_attention(q, k_q, v_q, lengths, k_scale: float, v_scale: float):
    """Single-token GQA attention, batch of independent sequences.

    q:        f32 [B, H, Dh]
    k_q, v_q: int8 [B, Hkv, L, Dh]  (C8 cache, static scales)
    lengths:  int32 [B]             valid entries per sequence
    Returns f32 [B, H, Dh].
    """
    B, H, Dh = q.shape
    _, Hkv, L, _ = k_q.shape
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, Dh)
    len2 = lengths.reshape(B, 1).astype(jnp.int32)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, k_scale=k_scale, v_scale=v_scale, dh=Dh),
        grid=(B, Hkv),
        in_specs=[
            pl.BlockSpec((1, 1, G, Dh), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, L, Dh), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, L, Dh), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1), lambda b, h: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, Dh), lambda b, h: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, Dh), jnp.float32),
        interpret=True,
    )(qg, k_q, v_q, len2)
    return out.reshape(B, H, Dh)


def _prefill_kernel(q_ref, k_ref, v_ref, off_ref, o_ref, *, k_scale, v_scale, dh):
    """One (sequence, kv-head) step: chunk q [T, G, Dh] vs cache [L, Dh]."""
    q = q_ref[0, 0]                                # [T, G, Dh]
    k = k_ref[0, 0].astype(jnp.float32) * k_scale  # [L, Dh]
    v = v_ref[0, 0].astype(jnp.float32) * v_scale
    off = off_ref[0, 0]
    T, G, _ = q.shape
    L = k.shape[0]
    scores = jnp.einsum("tgd,ld->tgl", q, k) * (1.0 / jnp.sqrt(jnp.float32(dh)))
    j = jnp.arange(L)[None, None, :]
    i = jnp.arange(T)[:, None, None]
    scores = jnp.where(j <= i + off, scores, -1e30)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[0, 0] = jnp.einsum("tgl,ld->tgd", p, v)


@functools.partial(jax.jit, static_argnames=("k_scale", "v_scale"))
def prefill_attention(q, k_q, v_q, pos_offset, k_scale: float, v_scale: float):
    """Causal chunked-prefill attention.

    q:        f32 [B, T, H, Dh]     chunk of queries starting at pos_offset
    k_q, v_q: int8 [B, Hkv, L, Dh]  cache already holding [0, off+T)
    pos_offset: int32 [B]           absolute position of q[:, 0] per sequence
    Returns f32 [B, T, H, Dh].
    """
    B, T, H, Dh = q.shape
    _, Hkv, L, _ = k_q.shape
    G = H // Hkv
    qg = q.reshape(B, T, Hkv, G, Dh).transpose(0, 2, 1, 3, 4)  # [B,Hkv,T,G,Dh]
    off2 = pos_offset.reshape(B, 1).astype(jnp.int32)
    out = pl.pallas_call(
        functools.partial(_prefill_kernel, k_scale=k_scale, v_scale=v_scale, dh=Dh),
        grid=(B, Hkv),
        in_specs=[
            pl.BlockSpec((1, 1, T, G, Dh), lambda b, h: (b, h, 0, 0, 0)),
            pl.BlockSpec((1, 1, L, Dh), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, L, Dh), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1), lambda b, h: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, T, G, Dh), lambda b, h: (b, h, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, T, G, Dh), jnp.float32),
        interpret=True,
    )(qg, k_q, v_q, off2)
    return out.transpose(0, 2, 1, 3, 4).reshape(B, T, H, Dh)
