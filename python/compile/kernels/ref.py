"""Pure-jnp oracles for every Pallas kernel in this package.

These are the CORE correctness signal: pytest sweeps shapes/dtypes with
hypothesis and asserts the Pallas kernels (interpret=True) match these
references to tight tolerances.
"""

from __future__ import annotations

import jax.numpy as jnp


def _softmax(x):
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def quant_matmul_ref(x_q, x_s, w_q, w_s):
    """W4A8 matmul oracle.

    x_q: int8 [M, K], x_s: f32 [M, 1] per-row activation scales.
    w_q: int8 [K, N] (int4-valued), w_s: f32 [N] per-channel weight scales.
    Returns f32 [M, N] = (x_q*x_s) @ (w_q*w_s).
    """
    acc = jnp.dot(
        x_q.astype(jnp.float32), w_q.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    return acc * x_s * w_s[None, :]


def rmsnorm_ref(x, g, eps=1e-6):
    """Plain RMSNorm: x * rsqrt(mean(x^2) + eps) * g."""
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * (1.0 / jnp.sqrt(ms + eps)) * g[None, :]


def rmsnorm_quant_ref(x, g, eps=1e-6):
    """RMSNorm followed by dynamic A8 quantization.

    x: f32 [M, D], g: f32 [D].
    Returns (q int8 [M, D], s f32 [M, 1]) with rmsnorm(x)*g ≈ q*s.
    """
    y = rmsnorm_ref(x, g, eps)
    s = jnp.maximum(jnp.max(jnp.abs(y), axis=-1, keepdims=True) / 127.0, 1e-8)
    q = jnp.clip(jnp.round(y / s), -127, 127).astype(jnp.int8)
    return q, s.astype(jnp.float32)


def swiglu_ref(gate, up):
    """SwiGLU elementwise: silu(gate) * up."""
    return (gate * jnp.reciprocal(1.0 + jnp.exp(-gate))) * up


def decode_attention_ref(q, k_q, v_q, k_scale, v_scale, lengths):
    """Single-token GQA attention against a quantized KV cache.

    q:        f32 [B, H, Dh]       query for the current token
    k_q, v_q: int8 [B, Hkv, L, Dh] quantized cache (C8)
    k_scale, v_scale: f32 scalars  static cache scales
    lengths:  int32 [B]            valid cache entries per sequence
    Returns f32 [B, H, Dh].
    """
    B, H, Dh = q.shape
    Hkv, L = k_q.shape[1], k_q.shape[2]
    group = H // Hkv
    k = jnp.repeat(k_q.astype(jnp.float32) * k_scale, group, axis=1)
    v = jnp.repeat(v_q.astype(jnp.float32) * v_scale, group, axis=1)
    scores = jnp.einsum("bhd,bhld->bhl", q, k) / jnp.sqrt(jnp.float32(Dh))
    mask = jnp.arange(L)[None, None, :] < lengths[:, None, None]
    scores = jnp.where(mask, scores, -1e30)
    p = _softmax(scores)
    return jnp.einsum("bhl,bhld->bhd", p, v)


def prefill_attention_ref(q, k_q, v_q, k_scale, v_scale, pos_offset):
    """Causal chunked-prefill attention against the quantized cache.

    q:        f32 [B, T, H, Dh]    queries for a chunk starting at pos_offset
    k_q, v_q: int8 [B, Hkv, L, Dh] cache that already contains entries
                                   [0, pos_offset + T) for this sequence
    pos_offset: int32 scalar       absolute position of q[:, 0]
    Returns f32 [B, T, H, Dh]. Query i attends to cache[j] for
    j <= pos_offset + i.
    """
    B, T, H, Dh = q.shape
    Hkv, L = k_q.shape[1], k_q.shape[2]
    group = H // Hkv
    k = jnp.repeat(k_q.astype(jnp.float32) * k_scale, group, axis=1)
    v = jnp.repeat(v_q.astype(jnp.float32) * v_scale, group, axis=1)
    scores = jnp.einsum("bthd,bhld->bhtl", q, k) / jnp.sqrt(jnp.float32(Dh))
    j = jnp.arange(L)[None, None, None, :]
    i = jnp.arange(T)[None, None, :, None]
    mask = j <= (i + pos_offset)
    scores = jnp.where(mask, scores, -1e30)
    p = _softmax(scores)
    return jnp.einsum("bhtl,bhld->bthd", p, v)
