"""Fused RMSNorm + A8 activation quantization as a Pallas kernel.

On NorthPole every activation tensor leaving a compute block is re-quantized
to the layer's activation precision before it is written to core memory
(§III-B). Fusing the norm with the quantizer keeps the f32 intermediate
entirely inside the kernel (VMEM), exactly like the chip never materializes
the f32 tensor in shared memory.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_quant_kernel(x_ref, g_ref, q_ref, s_ref, *, eps: float):
    x = x_ref[...]
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * (1.0 / jnp.sqrt(ms + eps)) * g_ref[...][None, :]
    s = jnp.maximum(jnp.max(jnp.abs(y), axis=-1, keepdims=True) / 127.0, 1e-8)
    q_ref[...] = jnp.clip(jnp.round(y / s), -127, 127).astype(jnp.int8)
    s_ref[...] = s.astype(jnp.float32)


def _pick_block(dim: int, pref: int) -> int:
    b = min(dim, pref)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("eps", "bm"))
def rmsnorm_quant(x, g, eps: float = 1e-6, bm: int = 128):
    """RMSNorm then dynamic symmetric int8 quantization, fused.

    x: f32 [M, D]; g: f32 [D].
    Returns (q int8 [M, D], s f32 [M, 1]).
    The row dimension is blocked; D stays whole (the norm is a full-row
    reduction, the natural NorthPole layout keeps a row within one core
    group).
    """
    M, D = x.shape
    bm = _pick_block(M, bm)
    grid = (M // bm,)
    q, s = pl.pallas_call(
        functools.partial(_rmsnorm_quant_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, D), lambda m: (m, 0)),
            pl.BlockSpec((D,), lambda m: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bm, D), lambda m: (m, 0)),
            pl.BlockSpec((bm, 1), lambda m: (m, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, D), jnp.int8),
            jax.ShapeDtypeStruct((M, 1), jnp.float32),
        ],
        interpret=True,
    )(x, g)
    return q, s
