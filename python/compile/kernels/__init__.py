"""Layer-1 Pallas kernels (interpret=True) + pure-jnp oracles (ref.py)."""

from .quant_matmul import quant_matmul
from .rmsnorm import rmsnorm_quant
from .swiglu import swiglu
from .attention import decode_attention, prefill_attention

__all__ = [
    "quant_matmul",
    "rmsnorm_quant",
    "swiglu",
    "decode_attention",
    "prefill_attention",
]
