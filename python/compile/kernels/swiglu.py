"""Fused SwiGLU gate as a Pallas kernel: silu(gate) * up.

A small elementwise kernel, but fusing it keeps the two f32 matmul outputs
from round-tripping through "off-core" memory between the MLP's up
projection and down projection — the NorthPole MLP block computes the whole
gate on-card (§III, Fig 2).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _swiglu_kernel(g_ref, u_ref, o_ref):
    g = g_ref[...]
    o_ref[...] = g * jnp.reciprocal(1.0 + jnp.exp(-g)) * u_ref[...]


def _pick_block(dim: int, pref: int) -> int:
    b = min(dim, pref)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def swiglu(gate, up, bm: int = 128, bn: int = 512):
    """silu(gate) * up, elementwise over [M, N]."""
    M, N = gate.shape
    bm = _pick_block(M, bm)
    bn = _pick_block(N, bn)
    grid = (M // bm, N // bn)
    return pl.pallas_call(
        _swiglu_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda m, n: (m, n)),
            pl.BlockSpec((bm, bn), lambda m, n: (m, n)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=True,
    )(gate, up)
