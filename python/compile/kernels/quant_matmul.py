"""W4A8 quantized matmul as a Pallas kernel.

This is the NorthPole core-array analog: int4 weights stay resident in
"on-chip" memory (VMEM blocks), int8 activations stream through, and the
product accumulates at full precision — §II-A's "all weights reside on-chip"
dataflow expressed as a Pallas BlockSpec schedule.

Hardware adaptation (DESIGN.md §3): the 16x16 NorthPole core array doing
int-MAC is mapped to MXU-shaped tiles — values are dequantized at the VMEM
edge and fed to the matrix unit with f32 accumulation, mirroring the
core-array accumulators. Tiles default to multiples of (8, 128) so the same
BlockSpecs lower cleanly for a real TPU; interpret=True is used on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _qmm_kernel(x_ref, w_ref, ws_ref, o_ref, *, nk: int):
    """One (bm, bn) output tile; grid dim 2 walks the K reduction."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # Dequantize at the VMEM edge; accumulate in f32 (MXU-style).
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    o_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _finish():
        # Per-output-channel weight scales applied once, at the end.
        o_ref[...] = o_ref[...] * ws_ref[...][None, :]


def _pick_block(dim: int, pref: int) -> int:
    """Largest divisor of dim that is <= pref (keeps grids exact)."""
    b = min(dim, pref)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def quant_matmul(x_q, x_s, w_q, w_s, bm: int = 128, bn: int = 128, bk: int = 256):
    """Compute (x_q * x_s) @ (w_q * w_s) with integer inputs.

    x_q: int8 [M, K]; x_s: f32 [M, 1] per-row scales (A8 dynamic).
    w_q: int8 [K, N] holding int4 values; w_s: f32 [N] per-channel scales.
    Returns f32 [M, N].
    """
    M, K = x_q.shape
    K2, N = w_q.shape
    assert K == K2, (K, K2)
    bm = _pick_block(M, bm)
    bn = _pick_block(N, bn)
    bk = _pick_block(K, bk)
    nk = K // bk
    grid = (M // bm, N // bn, nk)

    out = pl.pallas_call(
        functools.partial(_qmm_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda m, n, k: (m, k)),
            pl.BlockSpec((bk, bn), lambda m, n, k: (k, n)),
            pl.BlockSpec((bn,), lambda m, n, k: (n,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=True,
    )(x_q, w_q, w_s)
    # Per-row activation scale is a rank-1 broadcast; cheaper outside the grid.
    return out * x_s


def vmem_bytes(bm: int, bn: int, bk: int) -> int:
    """Estimated VMEM working set of one grid step (for the perf model)."""
    return bm * bk * 1 + bk * bn * 1 + bn * 4 + bm * bn * 4
