"""AOT export contract: manifest completeness + HLO text well-formedness.

The rust runtime consumes exactly what export() writes; these tests pin the
contract (stage inventory, signatures, tuple return convention).
"""

import json
import os

import numpy as np
import pytest

from compile import aot
from compile import model as M

CFG = M.CONFIGS["granite-test"]


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    params = M.init_params(CFG, seed=0)
    manifest = aot.export(CFG, params, str(out))
    return str(out), manifest


def test_stage_inventory(exported):
    _, man = exported
    names = set(man["stages"])
    want = {"embed_prefill", "embed_decode"}
    for i in range(CFG.n_layers):
        want |= {f"attn_prefill_{i}", f"attn_decode_{i}",
                 f"mlp_prefill_{i}", f"mlp_decode_{i}"}
    for j in range(CFG.lmhead_shards):
        want |= {f"lmhead_{j}", f"lmhead1_{j}"}
    assert names == want


def test_all_files_exist_and_parse_as_hlo(exported):
    out, man = exported
    for name, st in man["stages"].items():
        path = os.path.join(out, st["file"])
        assert os.path.exists(path), name
        text = open(path).read()
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name


def test_large_constants_are_not_elided(exported):
    """Weights are the artifact: the default as_hlo_text() elides big
    constants as `constant({...})`, which the rust-side text parser fills
    with garbage. Regression guard for that bug."""
    out, man = exported
    for name, st in man["stages"].items():
        text = open(os.path.join(out, st["file"])).read()
        assert "constant({...})" not in text, f"{name}: elided constant"
    # a weight-bearing stage must be substantially larger than its skeleton
    big = os.path.getsize(os.path.join(out, man["stages"]["mlp_decode_0"]["file"]))
    assert big > 50_000, f"mlp stage suspiciously small: {big} B"


def test_signatures(exported):
    _, man = exported
    B, T, D = CFG.batch_slots, CFG.prefill_chunk, CFG.d_model
    L, Hkv, Dh = CFG.max_context, CFG.n_kv_heads, CFG.d_head
    st = man["stages"]

    assert st["embed_prefill"]["inputs"] == [{"shape": [1, T], "dtype": "int32"}]
    assert st["embed_prefill"]["outputs"] == [{"shape": [1, T, D], "dtype": "float32"}]
    assert st["embed_decode"]["inputs"] == [{"shape": [B], "dtype": "int32"}]

    ap = st["attn_prefill_0"]
    assert ap["inputs"][0] == {"shape": [1, T, D], "dtype": "float32"}
    assert ap["inputs"][1] == {"shape": [B, Hkv, L, Dh], "dtype": "int8"}
    assert ap["inputs"][3] == {"shape": [], "dtype": "int32"}
    assert [o["shape"] for o in ap["outputs"]] == [[1, T, D], [B, Hkv, L, Dh], [B, Hkv, L, Dh]]

    ad = st["attn_decode_0"]
    assert ad["inputs"][0] == {"shape": [B, D], "dtype": "float32"}
    assert ad["inputs"][3] == {"shape": [B], "dtype": "int32"}

    lm = st["lmhead_0"]
    assert lm["outputs"] == [{"shape": [B, CFG.shard_vocab], "dtype": "float32"}]


def test_manifest_config_block(exported):
    _, man = exported
    c = man["config"]
    assert c["param_count"] == CFG.param_count()
    assert c["k_scale"] == CFG.k_scale
    assert man["format"] == "hlo-text/return-tuple"


def test_checkpoint_roundtrip(tmp_path):
    """Weights baked from a checkpoint produce different HLO constants."""
    params = M.init_params(CFG, seed=0)
    ck = tmp_path / "p.npz"
    np.savez(ck, **{k: v * 0.5 for k, v in params.items()})
    loaded = aot.load_params(CFG, str(ck), seed=0)
    assert np.allclose(loaded["embed"], params["embed"] * 0.5)
    missing = aot.load_params(CFG, str(tmp_path / "nope.npz"), seed=0)
    assert np.allclose(missing["embed"], params["embed"])
