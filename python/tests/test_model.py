"""L2 correctness: staged execution (the rust runtime's contract) vs the
whole-model oracle `forward_ref`, plus shape/config invariants.

`StagedDriver` is a python mirror of rust/src/runtime's stage composition:
per-sequence chunked prefill into a cache slot, then batched decode steps.
If this matches forward_ref, the artifact contract is correct.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model as M
from compile import quant

CFG = M.CONFIGS["granite-test"]


@pytest.fixture(scope="module")
def qp():
    params = M.init_params(CFG, seed=0)
    return M.quantize_params(params, CFG)


class StagedDriver:
    """Compose the stage functions exactly the way the rust coordinator does."""

    def __init__(self, qp, cfg):
        self.qp, self.cfg = qp, cfg
        B, Hkv, L, Dh = cfg.batch_slots, cfg.n_kv_heads, cfg.max_context, cfg.d_head
        self.caches = [
            (jnp.zeros((B, Hkv, L, Dh), jnp.int8),
             jnp.zeros((B, Hkv, L, Dh), jnp.int8))
            for _ in range(cfg.n_layers)
        ]

    def prefill(self, tokens: np.ndarray, slot: int):
        """tokens i32[P] -> hidden of last prompt token, f32[D]."""
        cfg, qp = self.cfg, self.qp
        T = cfg.prefill_chunk
        P = len(tokens)
        n_chunks = (P + T - 1) // T
        last_h = None
        for c in range(n_chunks):
            chunk = tokens[c * T:(c + 1) * T]
            pad = T - len(chunk)
            padded = np.concatenate([chunk, np.zeros(pad, np.int32)]).astype(np.int32)
            h = M.embed_prefill_stage(qp, cfg, jnp.asarray(padded[None]))
            off = jnp.int32(c * T)
            for i in range(cfg.n_layers):
                kc, vc = self.caches[i]
                h, kc, vc = M.attn_prefill_stage(
                    qp, cfg, i, h, kc, vc, jnp.int32(slot), off)
                self.caches[i] = (kc, vc)
                h = M.mlp_stage(qp, cfg, i, h)
            last_h = h[0, (len(chunk) - 1) if pad else T - 1]
        return last_h

    def decode_step(self, tokens: np.ndarray, positions: np.ndarray):
        """One batched decode step. tokens i32[B], positions i32[B].
        Returns hidden f32[B, D] (pre-lmhead)."""
        cfg, qp = self.cfg, self.qp
        h = M.embed_decode_stage(qp, cfg, jnp.asarray(tokens))
        pos = jnp.asarray(positions)
        for i in range(cfg.n_layers):
            kc, vc = self.caches[i]
            h, kc, vc = M.attn_decode_stage(qp, cfg, i, h, kc, vc, pos)
            self.caches[i] = (kc, vc)
            h = M.mlp_stage(qp, cfg, i, h)
        return h

    def logits(self, h):
        cfg, qp = self.cfg, self.qp
        return jnp.concatenate(
            [M.lmhead_stage(qp, cfg, j, h) for j in range(cfg.lmhead_shards)],
            axis=-1)


def test_staged_prefill_matches_forward_ref(qp):
    """Chunked per-slot prefill == full-batch oracle (last-token logits)."""
    r = np.random.default_rng(0)
    P = CFG.prefill_chunk * 2 + 3  # exercises padding in the last chunk
    tokens = r.integers(0, CFG.vocab, (2, P)).astype(np.int32)
    want = np.asarray(M.forward_ref(qp, CFG, jnp.asarray(tokens)))  # [2,P,V]

    drv = StagedDriver(qp, CFG)
    for s in range(2):
        h_last = drv.prefill(tokens[s], slot=s)
        got = np.asarray(drv.logits(h_last[None]))[0]
        np.testing.assert_allclose(got, want[s, P - 1], rtol=2e-3, atol=2e-3)


def test_staged_decode_matches_forward_ref(qp):
    """Prefill P tokens then greedily decode: logits at each step must match
    the oracle run on the growing sequence."""
    r = np.random.default_rng(1)
    P, G = 5, 4
    tokens = r.integers(0, CFG.vocab, P).astype(np.int32)

    drv = StagedDriver(qp, CFG)
    h = drv.prefill(tokens, slot=0)
    seq = list(tokens)
    for step in range(G):
        logits = np.asarray(drv.logits(h[None]))[0]
        want_full = np.asarray(M.forward_ref(
            qp, CFG, jnp.asarray(np.array(seq, np.int32)[None])))
        np.testing.assert_allclose(
            logits, want_full[0, -1], rtol=2e-3, atol=2e-3)
        nxt = int(logits.argmax())
        seq.append(nxt)
        hb = drv.decode_step(
            np.full(CFG.batch_slots, nxt, np.int32),
            np.full(CFG.batch_slots, len(seq) - 1, np.int32))
        h = hb[0]


def test_staged_decode_slots_are_independent(qp):
    """Writing into slot 1 must not disturb slot 0's cache/logits."""
    r = np.random.default_rng(2)
    t0 = r.integers(0, CFG.vocab, 6).astype(np.int32)
    t1 = r.integers(0, CFG.vocab, 9).astype(np.int32)

    solo = StagedDriver(qp, CFG)
    h_solo = solo.prefill(t0, slot=0)

    both = StagedDriver(qp, CFG)
    both.prefill(t1, slot=1)
    h_both = both.prefill(t0, slot=0)
    np.testing.assert_allclose(
        np.asarray(h_solo), np.asarray(h_both), rtol=1e-5, atol=1e-6)


def test_lmhead_shards_concatenate_to_full_vocab(qp):
    r = np.random.default_rng(3)
    h = r.standard_normal((3, CFG.d_model)).astype(np.float32)
    full = np.concatenate(
        [np.asarray(M.lmhead_stage(qp, CFG, j, jnp.asarray(h)))
         for j in range(CFG.lmhead_shards)], axis=-1)
    assert full.shape == (3, CFG.vocab)
    # shard boundaries must tile the vocab exactly (no overlap): compare with
    # a single-shard config
    one = M.ModelConfig(**{**CFG.__dict__, "lmhead_shards": 1})
    whole = np.asarray(M.lmhead_stage(qp, one, 0, jnp.asarray(h)))
    np.testing.assert_allclose(full, whole, rtol=1e-5, atol=1e-6)


def test_rope_is_position_dependent_and_orthogonal():
    x = np.random.default_rng(4).standard_normal((4, 2, 16)).astype(np.float32)
    p0 = np.asarray(M.rope(jnp.asarray(x), jnp.zeros(4, jnp.int32), 1e4))
    p5 = np.asarray(M.rope(jnp.asarray(x), jnp.full(4, 5, jnp.int32), 1e4))
    assert not np.allclose(p0, p5)
    # rotation preserves norms
    np.testing.assert_allclose(
        np.linalg.norm(p5, axis=-1), np.linalg.norm(x, axis=-1), rtol=1e-5)
    # position 0 is identity
    np.testing.assert_allclose(p0, x, rtol=1e-5, atol=1e-6)


def test_param_count_formula():
    params = M.init_params(CFG, 0)
    total = sum(v.size for v in params.values())
    assert total == CFG.param_count()


def test_quantize_params_precision():
    params = M.init_params(CFG, 0)
    qp = M.quantize_params(params, CFG)
    q, s = qp["l0.wq"]
    assert q.dtype == np.int8
    assert q.max() <= 7 and q.min() >= -7  # W4 range
    assert s.shape == (q.shape[1],)       # per-output-channel


def test_configs_are_consistent():
    for name, cfg in M.CONFIGS.items():
        assert cfg.name == name
        assert cfg.d_model % cfg.n_heads == 0
        assert cfg.n_heads % cfg.n_kv_heads == 0
        assert cfg.vocab % cfg.lmhead_shards == 0
        assert cfg.d_head % 2 == 0  # rope needs even head dim
        assert cfg.param_count() > 0
