"""SiLQ algorithm smoke tests: quantizer math, STE gradients, and a short
train/fine-tune loop (full Fig 5 run happens via `make fig5`)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import silq, tasks

# vocab must cover the byte-level task alphabet (ASCII up to 'z'); the
# shapes are otherwise test-scale.
CFG = M.ModelConfig(
    name="silq-test", vocab=384, d_model=32, n_layers=2, n_heads=2,
    n_kv_heads=1, d_ff=64, batch_slots=4, prefill_chunk=8, max_context=32,
    lmhead_shards=4,
)


def test_lsq_weight_quantizes_to_grid():
    w = jnp.asarray(np.random.default_rng(0).standard_normal((16, 8)), jnp.float32)
    s = jnp.asarray(silq.init_weight_scale(np.asarray(w), 4))
    q = silq.lsq_weight(w, s, 4)
    # every value sits on an integer multiple of its channel scale
    ratios = np.asarray(q) / np.asarray(s)[None, :]
    np.testing.assert_allclose(ratios, np.round(ratios), atol=1e-4)
    assert np.abs(ratios).max() <= 7 + 1e-5  # W4 range


def test_lsq_gradients_flow_to_scales():
    w = jnp.asarray(np.random.default_rng(1).standard_normal((8, 4)), jnp.float32)
    s = jnp.full((4,), 0.1, jnp.float32)

    def loss(s):
        return jnp.sum(jnp.square(silq.lsq_weight(w, s, 4) - w))

    g = jax.grad(loss)(s)
    assert np.isfinite(np.asarray(g)).all()
    assert (np.asarray(g) != 0).any(), "scale gradient must be nonzero"


def test_act_quant_ste_is_identity_gradient():
    x = jnp.asarray([[0.3, -1.2, 2.0, 0.0]], jnp.float32)

    def f(x):
        return jnp.sum(silq.act_quant_ste(x, 8) * 2.0)

    g = np.asarray(jax.grad(f)(x))
    # interior elements get the straight-through gradient exactly; the
    # row-max element sits on the clip boundary where jnp.minimum splits
    # the subgradient (0.5x)
    np.testing.assert_allclose(g[0, [0, 1, 3]], 2.0, rtol=1e-6)
    assert g[0, 2] in (1.0, 2.0)


def test_student_forward_matches_shapes_and_is_finite():
    params = {k: jnp.asarray(v) for k, v in M.init_params(CFG, 0).items()}
    ws = {k: jnp.asarray(silq.init_weight_scale(np.asarray(v), 4))
          for k, v in params.items() if silq.is_quantized(k)}
    toks = jnp.asarray(np.random.default_rng(2).integers(0, CFG.vocab, (2, 12), dtype=np.int32))
    lg = silq.forward_student(params, ws, CFG, toks)
    assert lg.shape == (2, 12, CFG.vocab)
    assert np.isfinite(np.asarray(lg)).all()


@pytest.mark.slow
def test_short_training_reduces_loss_and_folds():
    teacher = silq.pretrain_teacher(CFG, steps=40, batch=8, seqlen=24,
                                    lr=3e-3, seed=0, log_every=100)
    sp, ws = silq.silq_finetune(CFG, teacher, steps=10, batch=8, seqlen=24,
                                lr=1e-3, seed=0, log_every=100)
    folded = silq.fold_lsq_into_params(sp, ws, CFG)
    # folded weights must round-trip through the inference quantizer with
    # little extra error (they already sit near the LSQ grid)
    qp = M.quantize_params(folded, CFG)
    toks = jnp.asarray(np.random.default_rng(3).integers(0, CFG.vocab, (2, 12), dtype=np.int32))
    lg = M.forward_ref(qp, CFG, toks)
    assert np.isfinite(np.asarray(lg)).all()


def test_benchmark_suite_scores_all_19():
    params = {k: jnp.asarray(v) for k, v in M.init_params(CFG, 0).items()}

    @jax.jit
    def fwd(toks):
        return M.forward_float(params, CFG, toks)

    scores = tasks.benchmark_suite(lambda t: fwd(jnp.asarray(t)), n_examples=8)
    assert len(scores) == 19
    for name, s in scores.items():
        assert 0.0 <= s <= 100.0, (name, s)
