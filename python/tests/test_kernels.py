"""L1 correctness: Pallas kernels (interpret=True) vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes; every kernel must match its ref to tight
tolerance. This is the CORE correctness signal for the compute layer.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import quant
from compile.kernels import (
    decode_attention,
    prefill_attention,
    quant_matmul,
    rmsnorm_quant,
    swiglu,
)
from compile.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


def rng(seed=0):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------- quant_matmul

@given(
    m=st.sampled_from([1, 3, 8, 16, 130]),
    k=st.sampled_from([8, 32, 64, 96]),
    n=st.sampled_from([4, 16, 32, 33]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_quant_matmul_matches_ref(m, k, n, seed):
    r = rng(seed)
    xq = r.integers(-127, 128, (m, k), dtype=np.int8)
    xs = (r.random((m, 1)) * 0.1 + 1e-3).astype(np.float32)
    wq = r.integers(-7, 8, (k, n), dtype=np.int8)
    ws = (r.random(n) * 0.1 + 1e-3).astype(np.float32)
    got = np.asarray(quant_matmul(xq, xs, wq, ws))
    want = np.asarray(ref.quant_matmul_ref(xq, xs, wq, ws))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@given(
    bm=st.sampled_from([8, 32, 128]),
    bn=st.sampled_from([16, 128]),
    bk=st.sampled_from([32, 256]),
)
@settings(max_examples=9, deadline=None)
def test_quant_matmul_block_shape_invariance(bm, bn, bk):
    """Result must not depend on the BlockSpec tiling."""
    r = rng(7)
    xq = r.integers(-127, 128, (64, 128), dtype=np.int8)
    xs = (r.random((64, 1)) * 0.1).astype(np.float32)
    wq = r.integers(-7, 8, (128, 64), dtype=np.int8)
    ws = (r.random(64) * 0.1).astype(np.float32)
    base = np.asarray(quant_matmul(xq, xs, wq, ws))
    tiled = np.asarray(quant_matmul(xq, xs, wq, ws, bm=bm, bn=bn, bk=bk))
    np.testing.assert_allclose(tiled, base, rtol=1e-6)


def test_quant_matmul_identity():
    """Identity weights at scale 1 reproduce the activations."""
    k = 16
    xq = np.arange(-8, 8, dtype=np.int8).reshape(1, k)
    xs = np.ones((1, 1), np.float32)
    wq = np.eye(k, dtype=np.int8)
    ws = np.ones(k, np.float32)
    got = np.asarray(quant_matmul(xq, xs, wq, ws))
    np.testing.assert_allclose(got, xq.astype(np.float32))


# ---------------------------------------------------------------- rmsnorm

@given(
    m=st.sampled_from([1, 2, 8, 130]),
    d=st.sampled_from([8, 32, 128]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_rmsnorm_quant_matches_ref(m, d, seed):
    r = rng(seed)
    x = r.standard_normal((m, d)).astype(np.float32) * 3.0
    g = r.standard_normal(d).astype(np.float32)
    q1, s1 = rmsnorm_quant(x, g)
    q2, s2 = ref.rmsnorm_quant_ref(x, g)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)


def test_rmsnorm_quant_range():
    r = rng(3)
    x = r.standard_normal((16, 64)).astype(np.float32) * 100
    g = np.ones(64, np.float32)
    q, s = rmsnorm_quant(x, g)
    q = np.asarray(q)
    assert q.max() <= 127 and q.min() >= -127
    # dequantized result approximates the norm within one quantization step
    y = np.asarray(q) * np.asarray(s)
    want = np.asarray(ref.rmsnorm_ref(x, g))
    assert np.abs(y - want).max() <= np.asarray(s).max() * 0.51


def test_rmsnorm_zero_row_is_finite():
    x = np.zeros((2, 16), np.float32)
    g = np.ones(16, np.float32)
    q, s = rmsnorm_quant(x, g)
    assert np.isfinite(np.asarray(s)).all()
    assert (np.asarray(q) == 0).all()


# ---------------------------------------------------------------- swiglu

@given(
    m=st.sampled_from([1, 8, 128]),
    n=st.sampled_from([8, 512, 768]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_swiglu_matches_ref(m, n, seed):
    r = rng(seed)
    g = r.standard_normal((m, n)).astype(np.float32) * 4
    u = r.standard_normal((m, n)).astype(np.float32) * 4
    got = np.asarray(swiglu(g, u))
    want = np.asarray(ref.swiglu_ref(g, u))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------- attention

@given(
    b=st.sampled_from([1, 2, 4]),
    hkv=st.sampled_from([1, 2]),
    group=st.sampled_from([1, 2, 4]),
    l=st.sampled_from([4, 16, 64]),
    dh=st.sampled_from([8, 32]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_decode_attention_matches_ref(b, hkv, group, l, dh, seed):
    r = rng(seed)
    h = hkv * group
    q = r.standard_normal((b, h, dh)).astype(np.float32)
    kq = r.integers(-127, 128, (b, hkv, l, dh), dtype=np.int8)
    vq = r.integers(-127, 128, (b, hkv, l, dh), dtype=np.int8)
    lens = r.integers(1, l + 1, b).astype(np.int32)
    got = np.asarray(decode_attention(q, kq, vq, lens, 0.02, 0.03))
    want = np.asarray(ref.decode_attention_ref(q, kq, vq, 0.02, 0.03, lens))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


def test_decode_attention_respects_length_mask():
    """Entries beyond `lengths` must not affect the output."""
    r = rng(11)
    b, hkv, g, l, dh = 2, 2, 2, 16, 8
    q = r.standard_normal((b, hkv * g, dh)).astype(np.float32)
    kq = r.integers(-127, 128, (b, hkv, l, dh), dtype=np.int8)
    vq = r.integers(-127, 128, (b, hkv, l, dh), dtype=np.int8)
    lens = np.array([5, 9], np.int32)
    base = np.asarray(decode_attention(q, kq, vq, lens, 0.02, 0.03))
    kq2, vq2 = kq.copy(), vq.copy()
    kq2[0, :, 5:] = 99
    vq2[0, :, 5:] = -99
    kq2[1, :, 9:] = 99
    vq2[1, :, 9:] = -99
    pert = np.asarray(decode_attention(q, kq2, vq2, lens, 0.02, 0.03))
    np.testing.assert_allclose(pert, base, rtol=1e-6)


@given(
    b=st.sampled_from([1, 2]),
    hkv=st.sampled_from([1, 2]),
    group=st.sampled_from([1, 2]),
    t=st.sampled_from([1, 4, 8]),
    dh=st.sampled_from([8, 32]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_prefill_attention_matches_ref(b, hkv, group, t, dh, seed):
    r = rng(seed)
    l = 32
    h = hkv * group
    q = r.standard_normal((b, t, h, dh)).astype(np.float32)
    kq = r.integers(-127, 128, (b, hkv, l, dh), dtype=np.int8)
    vq = r.integers(-127, 128, (b, hkv, l, dh), dtype=np.int8)
    offs = r.integers(0, l - t + 1, b).astype(np.int32)
    got = np.asarray(prefill_attention(q, kq, vq, offs, 0.02, 0.03))
    want = np.stack([
        np.asarray(ref.prefill_attention_ref(
            q[i:i + 1], kq[i:i + 1], vq[i:i + 1], 0.02, 0.03, offs[i]))[0]
        for i in range(b)
    ])
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


def test_prefill_attention_is_causal():
    """Future cache entries (j > off + i) must not affect query i."""
    r = rng(13)
    b, hkv, g, t, l, dh = 1, 1, 2, 4, 16, 8
    q = r.standard_normal((b, t, hkv * g, dh)).astype(np.float32)
    kq = r.integers(-127, 128, (b, hkv, l, dh), dtype=np.int8)
    vq = r.integers(-127, 128, (b, hkv, l, dh), dtype=np.int8)
    off = np.array([3], np.int32)
    base = np.asarray(prefill_attention(q, kq, vq, off, 0.02, 0.03))
    kq2, vq2 = kq.copy(), vq.copy()
    kq2[:, :, 3 + t:] = 99   # strictly beyond the last query's horizon
    vq2[:, :, 3 + t:] = -99
    pert = np.asarray(prefill_attention(q, kq2, vq2, off, 0.02, 0.03))
    np.testing.assert_allclose(pert, base, rtol=1e-6)


# ---------------------------------------------------------------- quant helpers

@given(
    shape=st.sampled_from([(4, 8), (16, 16), (128, 3)]),
    bits=st.sampled_from([8, 4, 2]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_quant_dynamic_roundtrip_error_bounded(shape, bits, seed):
    r = rng(seed)
    x = r.standard_normal(shape).astype(np.float32)
    q, s = quant.quant_dynamic(x, bits)
    y = np.asarray(q).astype(np.float32) * np.asarray(s)
    # error is at most half a step per element
    step = np.asarray(s)
    assert (np.abs(y - x) <= 0.5 * step + 1e-7).all()


@given(k=st.sampled_from([2, 8, 64]), n=st.sampled_from([1, 5, 16]),
       seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_pack_unpack_int4_roundtrip(k, n, seed):
    r = rng(seed)
    q = r.integers(-8, 8, (k, n), dtype=np.int8)
    packed = quant.pack_int4(q)
    assert packed.nbytes == q.nbytes // 2
    np.testing.assert_array_equal(quant.unpack_int4(packed), q)


@given(k=st.sampled_from([2, 8, 64]), n=st.sampled_from([1, 16]),
       seed=st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_unpack_int4_jnp_matches_np(k, n, seed):
    r = rng(seed)
    q = r.integers(-8, 8, (k, n), dtype=np.int8)
    packed = quant.pack_int4(q)
    np.testing.assert_array_equal(
        np.asarray(quant.unpack_int4_jnp(packed)), quant.unpack_int4(packed))


def test_quant_weight_per_channel():
    r = rng(5)
    w = r.standard_normal((32, 8)).astype(np.float32)
    w[:, 3] *= 100.0  # one hot channel must not wreck the others
    q, s = quant.quant_weight_np(w, 4)
    deq = q.astype(np.float32) * s
    rel = np.abs(deq - w).max(axis=0) / np.abs(w).max(axis=0)
    assert (rel < 0.15).all()
