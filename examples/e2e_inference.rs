//! END-TO-END driver (DESIGN.md §5 E2E): load a small *real* model from the
//! AOT artifacts, serve a batch of requests through the complete stack —
//! broker → sequence head → ring consensus → card chain with per-card
//! resident KV caches (credit-tracked framebuffers) → PJRT numerics —
//! and report real latency/throughput plus the NorthPole-scale projection.
//!
//! Run `make artifacts` first (and optionally `make fig5` so the served
//! weights are the SiLQ fine-tuned ones). Results recorded in
//! EXPERIMENTS.md §E2E.
//!
//!   cargo run --release --example e2e_inference [-- artifacts/granite-tiny]

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use npserve::broker::{Broker, Task};
use npserve::config::hw::RackSpec;
use npserve::metrics::BatchMetrics;
use npserve::runtime::Engine;
use npserve::service::{LlmInstance, SharedEngine};
use npserve::util::stats::fmt_time;

fn main() {
    let dir = std::env::args()
        .nth(1)
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts/granite-tiny"));
    if !dir.join("manifest.json").exists() {
        eprintln!("no artifacts at {dir:?} — run `make artifacts` first");
        std::process::exit(1);
    }

    println!("loading + compiling stages from {dir:?} ...");
    let t0 = Instant::now();
    let engine = SharedEngine(Arc::new(Engine::load(&dir).expect("engine")));
    let m = engine.manifest.clone();
    println!(
        "model {} ({:.2}M params, {} stages) compiled on {} in {}",
        m.model,
        m.param_count as f64 / 1e6,
        engine.stage_names().len(),
        engine.platform(),
        fmt_time(t0.elapsed().as_secs_f64()),
    );

    // the full §IV path: API-style tasks -> broker -> instance
    let inst = LlmInstance::start(engine);
    let broker = Broker::new();
    let queue = m.model.clone();

    // a small task battery in the synthetic language the model was trained
    // on (tasks.py): arithmetic, copy, reverse...
    let prompts = [
        "3+4=", "Cabc=", "7+2=", "Rab=", "5-3=", "M39=", "S4=", "Nccc=",
        "1+1=", "Cxy=", "8-1=", "P7=", "m28=", "s91=", "2+6=", "Fabc=",
    ];
    let n_req = prompts.len();
    println!("\nserving {n_req} requests through broker + card chain ...");
    let t1 = Instant::now();
    let mut channels = Vec::new();
    for (i, p) in prompts.iter().enumerate() {
        let ch = broker.post(&queue, Task {
            id: i as u64,
            priority: 1,
            body: p.to_string(),
            reply_to: 1000 + i as u64,
            retries: 0,
            resume_from: 0,
            prefix_hash: 0,
        });
        channels.push((p, ch));
    }
    let worker = inst.serve_broker(broker.clone(), &queue, vec![0, 1, 2], 8);

    for (p, ch) in channels {
        let mut out = String::new();
        while let Some(tok) = ch.recv() {
            out.push_str(&tok);
        }
        println!("  {:10} -> {:?}", p, out.trim_end_matches(';'));
    }
    broker.close(&queue);
    let served = worker.join().unwrap();
    let wall = t1.elapsed().as_secs_f64();

    // real wall-clock metrics per the paper's §VI-B definitions
    let recs = inst.records.lock().unwrap().clone();
    let met = BatchMetrics::from_records(&recs);
    println!("\n== measured (PJRT CPU, wall clock) ==");
    println!(
        "served {served} requests in {} | in {} tok, out {} tok",
        fmt_time(wall), met.n_in, met.n_out
    );
    println!(
        "TTFT {} | ITL {} | OTPS {:.0} tok/s | EOTPS {:.0} tok/s",
        fmt_time(met.ttft.mean()), fmt_time(met.itl.mean()), met.otps, met.eotps
    );

    // the same workload's NorthPole-scale projection from the timing model
    let rack = RackSpec::northpole_42u();
    let model = npserve::config::models::find_model("granite-3.3-8b").unwrap();
    let mapping = npserve::mapper::map_model(&model, 28, 2048, &rack).unwrap();
    println!("\n== NorthPole projection (granite-3.3-8b on 84 cards) ==");
    println!(
        "decode ITL ≈ {} per user (paper Table II: 2.8 ms)",
        fmt_time(mapping.itl_estimate(&rack.node.card.chip, 1024))
    );
    println!("e2e OK");
}
