//! Quickstart: map a model to NorthPole hardware, estimate its serving
//! characteristics, and run a short simulated workload.
//!
//!   cargo run --release --example quickstart

use npserve::config::hw::RackSpec;
use npserve::config::models::find_model;
use npserve::mapper::map_model;
use npserve::metrics::BatchMetrics;
use npserve::pipeline::sim::{simulate, SimConfig};
use npserve::util::stats::fmt_time;

fn main() {
    let rack = RackSpec::northpole_42u();
    let chip = rack.node.card.chip;

    // 1. pick a model from the zoo (Table I) and map it
    let model = find_model("granite-3.3-8b").expect("model zoo");
    let mapping = map_model(&model, 28, 2048, &rack).expect("fits on-chip");
    println!("== mapping (Fig 2) ==");
    println!(
        "{}: {} cards over {} nodes ({} pipeline stages, micro-batch {})",
        model.name,
        mapping.n_cards(),
        mapping.n_nodes(&rack),
        mapping.stages.len(),
        mapping.micro_batch
    );
    println!(
        "instances per rack: {} | max users: {} @2k, {} @4k",
        mapping.instances_per_rack(&rack),
        mapping.max_users(&chip, 2048),
        mapping.max_users(&chip, 4096)
    );

    // 2. analytic latency estimate from the calibrated chip model
    println!("\n== estimates ==");
    println!(
        "decode ITL ≈ {} (paper: 2.8 ms)",
        fmt_time(mapping.itl_estimate(&chip, 1024))
    );

    // 3. short simulated serving run (Table II methodology, small counts)
    println!("\n== simulated serving run ==");
    let rep = simulate(&mapping, &rack, SimConfig::table2(2048, 28, 28));
    let met = BatchMetrics::from_records(&rep.seqs);
    println!("| ctx  | batch | TTFT_s ms | ITL_s ms | ITPS_B   | OTPS_B   | EOTPS_B  |");
    println!("{}", met.table2_row(2048, 28));
    println!("\nnext: `cargo run --release --example e2e_inference` for real tokens via PJRT");
}
