//! Rack-scale multi-instance serving end to end, in-process (§I, §IV):
//! three instances lease cards from one shared inventory, consume one
//! model queue behind the model-routed OpenAI front door, and requests for
//! an unknown model come back as `model_not_found` instead of hanging.
//!
//!   cargo run --release --example rack_serve
//!
//! Numerics run on the stub-backend toy model (`runtime::testmodel`), so
//! no PJRT artifacts are needed; placements are real card leases.

use std::sync::Arc;

use npserve::api::http::http_request;
use npserve::api::ApiServer;
use npserve::config::hw::RackSpec;
use npserve::rack::{InstanceSpec, RackService};
use npserve::runtime::testmodel::ToyConfig;
use npserve::service::SharedEngine;

const MODEL: &str = "toy-testmodel";

fn main() {
    let svc = RackService::new(RackSpec::northpole_42u());
    for _ in 0..3 {
        let engine = SharedEngine(Arc::new(ToyConfig::small().engine()));
        let mut spec = InstanceSpec::live(MODEL, 16, engine);
        spec.max_tokens = 8; // leave prompt room in the toy's 32-token context
        svc.deploy(spec).expect("placement");
    }
    println!(
        "{} instances of `{MODEL}` leased {}/{} cards:",
        svc.instances().len(),
        svc.inventory().in_use(),
        svc.inventory().total()
    );
    for info in svc.instances() {
        println!(
            "  instance {}: cards {}..{}",
            info.id,
            info.first_card,
            info.first_card + info.n_cards
        );
    }

    let api = ApiServer::serve_routed("127.0.0.1:0", svc.broker().clone(), svc.admission())
        .expect("bind");
    println!("front door at http://{}", api.addr());

    // a valid request round-trips through whichever instance is free
    let body = format!(
        r#"{{"model":"{MODEL}","messages":[{{"role":"user","content":"3+4="}}],"max_tokens":6}}"#
    );
    let (st, resp) = http_request(api.addr(), "POST", "/v1/chat/completions", &body).unwrap();
    println!("\nPOST /v1/chat/completions (known model) -> {st}");
    println!("{}", String::from_utf8_lossy(&resp));

    // an unknown model is rejected with an OpenAI-shaped typed error
    let body = r#"{"model":"gpt-oss-9000","messages":[{"role":"user","content":"hi"}]}"#;
    let (st, resp) = http_request(api.addr(), "POST", "/v1/chat/completions", body).unwrap();
    println!("\nPOST /v1/chat/completions (unknown model) -> {st}");
    println!("{}", String::from_utf8_lossy(&resp));

    print!("\n{}", svc.fleet_metrics().report());
    svc.shutdown_all();
    println!("rack shut down; all cards released ({} in use)", svc.inventory().in_use());
}
