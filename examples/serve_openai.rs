//! Serve a model behind the OpenAI streaming chat-completions endpoint and
//! exercise it with in-process HTTP clients — the full §IV cloud path:
//! HTTP → broker (priority queues) → LLM instance → SSE stream back.
//!
//!   cargo run --release --example serve_openai [-- artifacts/granite-tiny]

use std::path::PathBuf;
use std::sync::Arc;

use npserve::api::http::http_request;
use npserve::api::ApiServer;
use npserve::broker::Broker;
use npserve::runtime::Engine;
use npserve::service::{LlmInstance, SharedEngine};

fn main() {
    let dir = std::env::args()
        .nth(1)
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts/granite-tiny"));
    if !dir.join("manifest.json").exists() {
        eprintln!("no artifacts at {dir:?} — run `make artifacts` first");
        std::process::exit(1);
    }
    let engine = SharedEngine(Arc::new(Engine::load(&dir).expect("engine")));
    let model = engine.manifest.model.clone();
    let inst = LlmInstance::start(engine);
    let broker = Broker::new();
    let worker = inst.serve_broker(broker.clone(), &model, vec![0, 1, 2], 8);
    let api = ApiServer::serve("127.0.0.1:0", broker.clone()).expect("bind");
    println!("serving `{model}` at http://{}", api.addr());

    // non-streaming completion
    let body = format!(
        r#"{{"model":"{model}","messages":[{{"role":"user","content":"3+4="}}],"max_tokens":4}}"#
    );
    let (st, resp) = http_request(api.addr(), "POST", "/v1/chat/completions", &body).unwrap();
    println!("\nPOST /v1/chat/completions -> {st}");
    println!("{}", String::from_utf8_lossy(&resp));

    // streaming completion (SSE)
    let body = format!(
        r#"{{"model":"{model}","stream":true,"messages":[{{"role":"user","content":"Cab="}}],"max_tokens":4}}"#
    );
    let (st, resp) = http_request(api.addr(), "POST", "/v1/chat/completions", &body).unwrap();
    println!("\nPOST /v1/chat/completions (stream) -> {st}");
    for line in String::from_utf8_lossy(&resp).lines().take(8) {
        if !line.is_empty() {
            println!("  {line}");
        }
    }

    broker.close(&model);
    let served = worker.join().unwrap();
    println!("\nserved {served} requests; shutting down.");
}
