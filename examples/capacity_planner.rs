//! Capacity planner: given a model, context length, and rack constraints,
//! report the §III-C / §VI-B tradeoffs a deployment engineer needs —
//! max simultaneous users, instances per rack, power, and latency.
//!
//!   cargo run --release --example capacity_planner [-- <model>]

use npserve::config::hw::RackSpec;
use npserve::config::models::{find_model, model_zoo};
use npserve::mapper::map_model;
use npserve::power::deployment_power;

fn main() {
    let name = std::env::args().nth(1).unwrap_or("granite-3.3-8b".into());
    let Some(model) = find_model(&name) else {
        eprintln!("unknown model `{name}`; known:");
        for m in model_zoo() {
            eprintln!("  {}", m.name);
        }
        std::process::exit(1);
    };
    let rack = RackSpec::northpole_42u();
    let chip = rack.node.card.chip;

    println!(
        "capacity plan: {} ({}), rack budget {:.1} kW air-cooled",
        model.name, model.precision, rack.power_budget_w / 1e3
    );
    println!(
        "| context | users | cards | nodes | inst/rack | ITL est | rack tok/s | power kW | of budget |"
    );
    println!(
        "|---------|-------|-------|-------|-----------|---------|------------|----------|-----------|"
    );
    for ctx in [1024u32, 2048, 4096, 8192] {
        // binary-search the largest mini-batch whose whole KV cache fits
        // on-chip at this context (the §III-C constraint); the mapping
        // shape itself depends on the batch, so each probe remaps
        let (mut lo, mut hi) = (0u32, 257u32);
        while hi - lo > 1 {
            let mid = (lo + hi) / 2;
            if map_model(&model, mid, ctx, &rack).is_ok() {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        let users = lo;
        if users == 0 {
            println!("| {ctx:>7} |     0 | context too large for on-chip KV |");
            continue;
        }
        let map = map_model(&model, users, ctx, &rack).unwrap();
        let inst = map.instances_per_rack(&rack);
        let itl = map.itl_estimate(&chip, ctx / 2);
        let rack_tps = inst as f64 * users as f64 / itl;
        let p = deployment_power(
            &rack,
            (inst * map.n_nodes(&rack)).min(rack.nodes_per_rack),
            inst * map.n_cards(),
            1.0,
        );
        println!(
            "| {ctx:>7} | {users:>5} | {:>5} | {:>5} | {inst:>9} | {:>6.2}ms | {rack_tps:>10.0} | {:>8.1} | {:>8.0}% |",
            map.n_cards(),
            map.n_nodes(&rack),
            itl * 1e3,
            p.total_w / 1e3,
            100.0 * p.budget_fraction(),
        );
    }
    println!("\n(the 2k/28 vs 4k/14 rows for granite-3.3-8b are Table II's configurations)");
}
