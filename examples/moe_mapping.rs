//! Fig 3: mapping mixture-of-experts models (gpt-oss-20b/120b) onto
//! NorthPole with tensor+pipeline parallelism over expert cards, plus the
//! virtual-circuit mechanism (§V-C) that toggles expert subsets without
//! reconfiguring on-chip memories.
//!
//!   cargo run --release --example moe_mapping

use npserve::card::{CardFpga, CircuitHop, CreditCounter, Packet};
use npserve::config::hw::RackSpec;
use npserve::config::models::find_model;
use npserve::mapper::map_model;

fn main() {
    let rack = RackSpec::northpole_42u();
    for name in ["gpt-oss-20b", "gpt-oss-120b"] {
        let model = find_model(name).unwrap();
        let map = map_model(&model, 28, 2048, &rack).unwrap();
        let moe = model.moe.unwrap();
        println!(
            "== {name}: {} experts/layer (top-{}), {} layers ==",
            moe.n_experts, moe.top_k, model.n_layers
        );
        println!(
            "{} cards | {} nodes | {} racks | {} stages",
            map.n_cards(),
            map.n_nodes(&rack),
            map.n_racks(&rack),
            map.stages.len()
        );
        // show one layer's card group (the Fig 3 box)
        for s in map.stages.iter().take(2) {
            println!("  stage `{}`: {} card(s)", s.label, s.cards.len());
        }
        println!("  ... lmhead: {} TP cards\n", map.stages.last().unwrap().cards.len());
    }

    // §V-C virtual circuits: one attention card feeding two different
    // expert-card groups; toggling the circuit id reroutes tensors with no
    // memory reconfiguration (the MoE fast path).
    println!("== virtual-circuit expert toggle (§V-C) ==");
    let attn = CardFpga::new(0, 4);
    let experts_a = CardFpga::new(1, 4);
    let experts_b = CardFpga::new(2, 4);
    attn.configure_circuit(CircuitHop {
        circuit: 0,
        dest: Some(experts_a.framebuffer.clone()),
        credits: Some(CreditCounter::new(4)),
    });
    attn.configure_circuit(CircuitHop {
        circuit: 1,
        dest: Some(experts_b.framebuffer.clone()),
        credits: Some(CreditCounter::new(4)),
    });
    for (tok, circuit) in [(101u64, 0u32), (102, 1), (103, 0)] {
        attn.emit(Packet { circuit, tag: tok, data: vec![0; 8] }).unwrap();
        println!("  token {tok} routed via circuit {circuit} (expert group {})",
                 if circuit == 0 { "A" } else { "B" });
    }
    assert_eq!(experts_a.framebuffer.consume().tag, 101);
    assert_eq!(experts_b.framebuffer.consume().tag, 102);
    assert_eq!(experts_a.framebuffer.consume().tag, 103);
    println!("expert groups received the expected tokens; MoE routing OK");
}
